"""Pluggable interconnect API tests.

Covers the preset registry, routing invariants as property tests over random
:class:`InterconnectSpec`s (every leg chain is connected src -> dst, legs only
traverse declared ports/links, per-port stats sum to the per-class and global
stats), link-override validation (unknown class -> actionable error), legacy
bit-identity (``ring``/``two_tier`` presets == the topology-derived fabric),
cycle/event bit-identity on a non-ring preset, and
:meth:`WriteTrackingTable.register_many` equivalence with per-write
registration.
"""

import random

import pytest

from repro.core import (
    Cluster,
    EngineKind,
    FabricModel,
    RegisteredWrite,
    SimConfig,
    Topology,
    WriteTrackingTable,
    build_fabric,
    get_fabric,
    get_scenario,
    list_fabrics,
    simulate,
)
from repro.core.interconnect import InterconnectSpec, resolve_fabric

FAST = SimConfig(workgroups=12, n_cus=4)

# small payloads keep the cycle-engine identity runs fast
SMALL = dict(payload_bytes=1 << 16, writes_per_step=2)
PRESETS = ("ring", "two_tier", "fat_tree", "rail_optimized", "torus2d")


def _segments_key(report):
    return sorted(
        (s.device, s.wg, s.phase, round(s.start_ns, 6), round(s.end_ns, 6))
        for s in report.segments
    )


def _spec_for(name: str, n: int, dpn, rng: random.Random) -> InterconnectSpec:
    """A randomly-parameterized preset spec (shared by the property tests)."""
    params = {}
    if name == "fat_tree":
        params = {
            "oversubscription": rng.choice([1.0, 2.0, 3.5, 8.0]),
            "nodes_per_leaf": rng.randint(1, 4),
        }
    elif name == "rail_optimized":
        params = {"rails": rng.randint(1, max(1, dpn or 1))}
    elif name == "torus2d":
        divisors = [d for d in range(1, n + 1) if n % d == 0]
        params = {"rows": rng.choice(divisors)}
    return build_fabric(name, n, devices_per_node=dpn, **params)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_fabric_registry_lists_builtins():
    names = list_fabrics()
    for name in PRESETS:
        assert name in names
        assert get_fabric(name) is not None
    with pytest.raises(KeyError) as e:
        get_fabric("warp_drive")
    assert "available" in str(e.value)


def test_spec_validation():
    with pytest.raises(ValueError):
        build_fabric("two_tier", 8, devices_per_node=3)  # not a divisor
    with pytest.raises(ValueError):
        build_fabric("fat_tree", 8, devices_per_node=2, oversubscription=0.5)
    with pytest.raises(ValueError):
        build_fabric("rail_optimized", 8, devices_per_node=2, rails=5)
    with pytest.raises(ValueError):
        build_fabric("torus2d", 8, rows=3)  # 3 does not divide 8
    spec = build_fabric("torus2d", 12, rows=3)
    assert spec.params["cols"] == 4


# ---------------------------------------------------------------------------
# routing invariants (property tests over random specs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_routing_invariants_random_specs(seed):
    """For random presets/shapes, every per-pair leg chain must (a) start at
    the source device and end at the destination device with consecutive legs
    sharing endpoints, and (b) ride only declared ports whose declared class
    matches the leg's."""
    rng = random.Random(seed)
    name = rng.choice(PRESETS)
    n = rng.choice([2, 3, 4, 6, 8, 12, 16, 24])
    divisors = [d for d in range(1, n + 1) if n % d == 0]
    dpn = rng.choice(divisors + [None])
    spec = _spec_for(name, n, dpn, rng)
    fm = FabricModel.from_spec(spec)
    table = fm.route_table()
    assert len(table) == n * (n - 1)
    for (src, dst), legs in table.items():
        assert legs, f"empty path {src}->{dst} on {spec.name}"
        assert legs[0].src == ("dev", src)
        assert legs[-1].dst == ("dev", dst)
        for a, b in zip(legs, legs[1:]):
            assert a.dst == b.src, f"disconnected chain {src}->{dst}: {legs}"
        for leg in legs:
            assert leg.hops >= 1
            assert leg.port in spec.ports, f"undeclared port {leg.port}"
            assert spec.ports[leg.port] == leg.cls
            assert leg.cls in spec.link_classes


@pytest.mark.parametrize("seed", range(4))
def test_per_port_stats_sum_to_class_and_global_stats(seed):
    """After random transfers (single and batched), the per-port counters
    must sum to the per-class counters, and the per-class message count must
    equal the total number of legs priced."""
    rng = random.Random(100 + seed)
    name = rng.choice(PRESETS)
    n = rng.choice([4, 6, 8, 12, 24])
    divisors = [d for d in range(1, n + 1) if n % d == 0]
    spec = _spec_for(name, n, rng.choice(divisors), rng)
    fm = FabricModel.from_spec(spec)
    n_msgs = 0
    n_legs = 0
    total_leg_bytes = 0
    for _ in range(150):
        src = rng.randrange(n)
        if rng.random() < 0.3:
            dsts = [d for d in range(n) if d != src]
            nbs = [rng.randrange(0, 4096) for _ in dsts]
            fm.transfer_batch(src, dsts, nbs, rng.random() * 1e5)
            n_msgs += len(dsts)
            for d, nb in zip(dsts, nbs):
                n_legs += len(fm.legs(src, d))
                total_leg_bytes += len(fm.legs(src, d)) * nb
        else:
            dst = rng.randrange(n)
            if dst == src:
                continue
            nb = rng.randrange(0, 4096)
            fm.transfer(src, dst, nb, rng.random() * 1e5)
            n_msgs += 1
            n_legs += len(fm.legs(src, dst))
            total_leg_bytes += len(fm.legs(src, dst)) * nb
    st = fm.stats
    assert st["messages"] == n_msgs
    cls_msgs = {c: st[c + "_messages"] for c in spec.link_classes}
    cls_bytes = {c: st[c + "_bytes"] for c in spec.link_classes}
    cls_queued = {c: st[c + "_queued_ns"] for c in spec.link_classes}
    assert sum(cls_msgs.values()) == n_legs
    assert sum(cls_bytes.values()) == total_leg_bytes
    # per-port sums == per-class sums, exactly (same float-add sequences
    # cannot be guaranteed across groupings, so compare with a tolerance for
    # the queued-ns float sums and exactly for the integer counters)
    port_msgs = {c: 0 for c in spec.link_classes}
    port_bytes = {c: 0 for c in spec.link_classes}
    port_queued = {c: 0.0 for c in spec.link_classes}
    for port, (m, b, q) in fm.port_stats.items():
        c = spec.ports[port]
        port_msgs[c] += m
        port_bytes[c] += b
        port_queued[c] += q
    assert port_msgs == cls_msgs
    assert port_bytes == cls_bytes
    for c in spec.link_classes:
        assert port_queued[c] == pytest.approx(cls_queued[c], rel=1e-9, abs=1e-6)


def test_transfer_batch_matches_sequential_on_graph_presets():
    """The vectorized same-issue pricing must stay bit-identical to
    per-message calls on the new presets too (fast path and fallback)."""
    rng = random.Random(7)
    for name in PRESETS:
        for n, dpn in ((24, 4), (8, 2)):
            spec_a = _spec_for(name, n, dpn, random.Random(42))
            spec_b = _spec_for(name, n, dpn, random.Random(42))
            f_seq = FabricModel.from_spec(spec_a)
            f_bat = FabricModel.from_spec(spec_b)
            for _ in range(12):
                src = rng.randrange(n)
                dsts = [d for d in range(n) if d != src]
                rng.shuffle(dsts)
                nbs = [rng.randrange(0, 8192) for _ in dsts]
                t = rng.random() * 1e5
                seq = [
                    f_seq.transfer(src, d, nb, t)
                    for d, nb in zip(dsts, nbs)
                ]
                assert f_bat.transfer_batch(src, dsts, nbs, t) == seq, (
                    name, n, dpn,
                )
            assert f_seq.stats == f_bat.stats, (name, n, dpn)


# ---------------------------------------------------------------------------
# link-class overrides: validated, never silently ignored
# ---------------------------------------------------------------------------


def test_link_override_unknown_class_is_actionable():
    with pytest.raises(ValueError) as e:
        build_fabric("two_tier", 8, devices_per_node=4, link_bw={"bogus": 5.0})
    msg = str(e.value)
    assert "bogus" in msg and "dci" in msg and "ici" in msg
    # rail_optimized has no "dci" class: the legacy alias must say so
    with pytest.raises(ValueError) as e:
        build_fabric(
            "rail_optimized", 8, devices_per_node=4, link_bw={"dci": 5.0}
        )
    assert "rail" in str(e.value)


def test_from_topology_validates_overrides():
    topo = Topology.two_tier(2, 4)
    f = FabricModel.from_topology(topo, link_bw={"dci": 5.0})
    assert f.spec.link_classes["dci"].bw_bytes_per_ns == 5.0
    with pytest.raises(ValueError) as e:
        FabricModel.from_topology(topo, link_bw={"nope": 5.0})
    assert "nope" in str(e.value) and "valid classes" in str(e.value)
    # unknown keyword overrides are rejected, not silently ignored
    with pytest.raises(ValueError) as e:
        FabricModel.from_topology(topo, dci_bw_gbps=5.0)
    assert "dci_bw_gbps" in str(e.value)
    # legacy scalar aliases keep working
    f2 = FabricModel.from_topology(topo, dci_link_bw_bytes_per_ns=5.0)
    assert f2.spec.link_classes["dci"].bw_bytes_per_ns == 5.0


def test_scenario_link_bw_override_validated_end_to_end():
    cfg = FAST.with_(engine=EngineKind.EVENT)
    with pytest.raises(ValueError) as e:
        simulate(
            "ring_allreduce", cfg, devices=8, closed_loop=True,
            devices_per_node=4, link_bw={"warp": 1.0},
        )
    assert "warp" in str(e.value)
    # a valid override slows the uplink and stretches the closed loop
    base = simulate(
        "ring_allreduce", cfg, devices=8, closed_loop=True,
        devices_per_node=4, collect_segments=False,
    )
    slow = simulate(
        "ring_allreduce", cfg, devices=8, closed_loop=True,
        devices_per_node=4, link_bw={"dci": 12.5 / 8},
        collect_segments=False,
    )
    assert slow.kernel_span_ns > base.kernel_span_ns
    assert slow.traffic["nonflag_reads"] == base.traffic["nonflag_reads"]


# ---------------------------------------------------------------------------
# legacy bit-identity and preset selection
# ---------------------------------------------------------------------------


def test_named_presets_bit_identical_to_topology_derived_fabric():
    """fabric="ring"/"two_tier" must reproduce the legacy topology-derived
    closed loop bit for bit — the guarantee that keeps the committed BENCH
    counters valid."""
    cfg = FAST.with_(engine=EngineKind.EVENT)
    legacy_flat = simulate("ring_allreduce", cfg, devices=6, closed_loop=True)
    named_flat = simulate(
        "ring_allreduce", cfg, devices=6, closed_loop=True, fabric="ring"
    )
    assert legacy_flat.traffic == named_flat.traffic
    assert legacy_flat.kernel_span_ns == named_flat.kernel_span_ns
    assert _segments_key(legacy_flat) == _segments_key(named_flat)

    legacy_tier = simulate(
        "all_to_all", cfg, devices=8, closed_loop=True, devices_per_node=4
    )
    named_tier = simulate(
        "all_to_all", cfg, devices=8, closed_loop=True, devices_per_node=4,
        fabric="two_tier",
    )
    assert legacy_tier.traffic == named_tier.traffic
    assert legacy_tier.kernel_span_ns == named_tier.kernel_span_ns
    assert _segments_key(legacy_tier) == _segments_key(named_tier)
    assert named_tier.meta["fabric_name"] == "two_tier"


@pytest.mark.parametrize("fabric", PRESETS)
@pytest.mark.parametrize(
    "name",
    ["ring_allreduce", "all_to_all", "pipeline_p2p", "hierarchical_allreduce"],
)
def test_every_closed_loop_scenario_runs_on_every_preset(name, fabric):
    cfg = FAST.with_(engine=EngineKind.EVENT)
    kw = dict(SMALL) if "allreduce" in name else {}
    r = simulate(
        name, cfg, devices=8, closed_loop=True, devices_per_node=4,
        fabric=fabric, collect_segments=False, **kw,
    )
    assert r.meta["fabric_name"] == fabric
    fs = r.meta["fabric"]
    assert fs["messages"] > 0
    # per-link-class stats exist for exactly the declared classes
    spec = build_fabric(fabric, 8, devices_per_node=4)
    for c in spec.link_classes:
        assert c + "_messages" in fs
    assert sum(fs[c + "_messages"] for c in spec.link_classes) >= fs["messages"]


def test_fat_tree_oversubscription_slows_cross_leaf_traffic():
    cfg = FAST.with_(engine=EngineKind.EVENT)
    kw = dict(devices=8, closed_loop=True, devices_per_node=2,
              collect_segments=False)
    r1 = simulate("all_to_all", cfg, fabric=build_fabric(
        "fat_tree", 8, devices_per_node=2, oversubscription=1.0), **kw)
    r8 = simulate("all_to_all", cfg, fabric=build_fabric(
        "fat_tree", 8, devices_per_node=2, oversubscription=8.0), **kw)
    assert r8.kernel_span_ns > r1.kernel_span_ns
    assert r8.meta["fabric"]["spine_messages"] == (
        r1.meta["fabric"]["spine_messages"]
    )
    # structural counters cannot move (flag_reads may: SPIN polls longer)
    assert r8.traffic["nonflag_reads"] == r1.traffic["nonflag_reads"]
    assert r8.wtt_enacted == r1.wtt_enacted


def test_rail_optimized_beats_single_uplink_on_incast():
    """k NICs per node drain the all_to_all incast faster than one gateway
    uplink — the rail-optimized design point."""
    cfg = FAST.with_(engine=EngineKind.EVENT)
    kw = dict(devices=8, closed_loop=True, devices_per_node=4,
              collect_segments=False)
    tier = simulate("all_to_all", cfg, fabric="two_tier", **kw)
    rail = simulate("all_to_all", cfg, fabric="rail_optimized", **kw)
    assert rail.kernel_span_ns < tier.kernel_span_ns
    assert rail.traffic["nonflag_reads"] == tier.traffic["nonflag_reads"]
    assert rail.wtt_enacted == tier.wtt_enacted
    # rail-aligned pairs cross with zero intra hops: strictly fewer ICI legs
    assert (
        rail.meta["fabric"]["ici_messages"]
        < tier.meta["fabric"]["ici_messages"]
    )


def test_cluster_accepts_preset_name_and_spec():
    cfg = FAST.with_(engine=EngineKind.EVENT).with_devices(8)
    sc = get_scenario("ring_allreduce")(cfg, closed_loop=True, **SMALL)
    by_name = Cluster(cfg, sc, fabric="torus2d").run()
    sc2 = get_scenario("ring_allreduce")(cfg, closed_loop=True, **SMALL)
    by_spec = Cluster(cfg, sc2, fabric=build_fabric("torus2d", 8)).run()
    assert by_name.traffic == by_spec.traffic
    assert by_name.kernel_span_ns == by_spec.kernel_span_ns
    with pytest.raises(ValueError):
        Cluster(cfg, sc, fabric=build_fabric("torus2d", 12))  # wrong size
    # a named preset on a *flat* scenario must not degenerate to one node:
    # fat_tree falls back to its own default (one-device nodes), so the
    # spine actually carries traffic
    sc3 = get_scenario("ring_allreduce")(cfg, closed_loop=True, **SMALL)
    flat_ft = Cluster(cfg, sc3, fabric="fat_tree").run()
    assert flat_ft.meta["n_nodes"] == 8
    assert flat_ft.meta["fabric"]["spine_messages"] > 0


def test_resolve_fabric_passthrough_and_default():
    assert resolve_fabric(None, 8) is None
    spec = resolve_fabric(None, 8, link_bw={"ici": 25.0})
    assert spec is not None and spec.name == "ring"
    assert spec.link_classes["ici"].bw_bytes_per_ns == 25.0
    spec2 = resolve_fabric(
        None, 8, devices_per_node=4, link_bw={"dci": 2.0}
    )
    assert spec2.name == "two_tier"
    with pytest.raises(ValueError):
        resolve_fabric(build_fabric("ring", 8), 12)


# ---------------------------------------------------------------------------
# cycle/event bit-identity on a non-ring preset
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fabric", ["fat_tree", "rail_optimized"])
def test_cycle_event_bit_identity_on_graph_preset(fabric):
    reports = {}
    for eng in (EngineKind.CYCLE, EngineKind.EVENT):
        cfg = FAST.with_(engine=eng)
        reports[eng] = simulate(
            "hierarchical_allreduce", cfg, devices=8, devices_per_node=2,
            fabric=fabric, **SMALL,
        )
    a, b = reports[EngineKind.CYCLE], reports[EngineKind.EVENT]
    assert a.traffic == b.traffic
    assert a.per_device == b.per_device
    assert a.kernel_span_ns == pytest.approx(b.kernel_span_ns)
    assert _segments_key(a) == _segments_key(b)


# ---------------------------------------------------------------------------
# WTT.register_many
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(5))
def test_register_many_bit_identical_to_sequential(seed):
    """Batched registration must pop exactly what per-write registration
    would: same (cycle, seq) groups, same stats, interleaved with singles."""
    rng = random.Random(seed)
    a = WriteTrackingTable(clock_ghz=1.5)
    b = WriteTrackingTable(clock_ghz=1.5)
    i = 0
    for _ in range(20):
        ws = [
            RegisteredWrite(
                wakeup_ns=rng.random() * 1e4,
                addr=64 * (i + j),
                data=i + j,
                seq=i + j,
            )
            for j in range(rng.randrange(0, 12))
        ]
        i += len(ws)
        if rng.random() < 0.5 and len(ws) == 1:
            a.register(ws[0])
        else:
            a.register_many(ws)
        for w in ws:
            b.register(w)
    assert a.stats.registered == b.stats.registered == i
    assert a.stats.max_pending == b.stats.max_pending
    while True:
        ca, ga = a.pop_next_group()
        cb, gb = b.pop_next_group()
        assert ca == cb
        assert [w.seq for w in ga] == [w.seq for w in gb]
        if ca is None:
            break


def test_register_many_fires_calendar_hook_with_earliest_cycle():
    wtt = WriteTrackingTable(clock_ghz=1.0)
    seen = []
    wtt.on_register = seen.append
    wtt.register_many(
        [
            RegisteredWrite(wakeup_ns=t, addr=64, data=1, seq=s)
            for s, t in enumerate([500.0, 100.0, 900.0])
        ]
    )
    assert seen == [100]
    wtt.register_many([])
    assert seen == [100]
