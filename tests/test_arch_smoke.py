"""Per-architecture smoke tests: REDUCED family-preserving configs, one
forward + train step on CPU, asserting output shapes and no NaNs (the full
configs are exercised only via the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config, reduced
from repro.models import Model

# model-forward-dominated: runs in the separate slow CI job, not the fast
# simulator suite
pytestmark = pytest.mark.slow

ARCHS = sorted(REGISTRY)
RNG = jax.random.PRNGKey(0)
B, S = 2, 32

RTOL = ATOL = 0.06


def _assert_serving_matches_forward(cfg, actual, desired):
    """Serving-path logits vs the training forward, MoE-flip aware.

    For non-MoE architectures the two paths must agree within the strict
    rtol/atol.  MoE architectures get a documented concession, because the
    divergence is provably fp-accumulation-order, not a cache/model bug:

    * ``jax.jit(m.forward)(...)`` equals eager ``m.forward(...)`` bit-exactly,
      and eager decode matches the forward within ~0.01 — the serving path's
      math is right.
    * The jitted decode step (and the eager python-loop serving path vs the
      XLA-compiled ``lax.scan`` forward) differ by 1-ulp bf16 rounding wherever
      XLA fuses a reduction differently; measured cache deltas at decode step
      0 are exactly 1 ulp.
    * At random init the router softmax is near-uniform, so top-k margins sit
      inside that 1-ulp noise: a handful of tokens flip one routed expert at
      some intermediate step (observed: ~5 flips over 32 steps x 4 layers),
      and each flip moves a few final logits by |w_i * (expert_a - expert_b)|
      ~ 0.1 while leaving the other ~98% of elements bit-comparable.

    So for MoE we require the strict tolerance on >= 90% of elements and a
    loose routing-flip bound (0.35, ~3x the largest observed flip excursion)
    on all of them.  A genuine KV-cache or state bug breaks 100% of elements
    by far more than 0.35 and still fails loudly.
    """
    actual = np.asarray(actual)
    desired = np.asarray(desired)
    if cfg.n_experts == 0:
        np.testing.assert_allclose(actual, desired, rtol=RTOL, atol=ATOL)
        return
    err = np.abs(actual - desired)
    strict = err <= ATOL + RTOL * np.abs(desired)
    frac = strict.mean()
    assert frac >= 0.90, (
        f"{(1 - frac):.1%} of logits outside strict tolerance — beyond what "
        "routing flips explain; suspect a real serving-path bug"
    )
    np.testing.assert_allclose(actual, desired, rtol=0.0, atol=0.35)


def _inputs(cfg):
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    embeds = None
    if cfg.frontend != "none":
        embeds = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32) * 0.02
    return tokens, embeds


@pytest.fixture(scope="module")
def models():
    cache = {}
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        m = Model(cfg)
        cache[arch] = (m, m.init(RNG))
    return cache


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(models, arch):
    m, params = models[arch]
    tokens, embeds = _inputs(m.cfg)
    logits, aux = m.forward(params, tokens, embeds=embeds)
    assert logits.shape == (B, S, m.cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(models, arch):
    m, params = models[arch]
    tokens, embeds = _inputs(m.cfg)

    def loss(p):
        return m.loss_fn(p, tokens, embeds=embeds)[0]

    l, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l))
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(models, arch):
    """Feeding tokens one-by-one through decode must reproduce the full
    forward's last-position logits (cache correctness across families)."""
    m, params = models[arch]
    tokens, embeds = _inputs(m.cfg)
    logits, _ = m.forward(params, tokens, embeds=embeds)
    caches = m.init_caches(B, S + 4)
    step = jax.jit(lambda p, c, t, pos, e: m.decode_step(p, c, t, pos, embeds=e))
    lg = None
    for t in range(S):
        emb_t = embeds[:, t : t + 1] if embeds is not None else None
        lg, caches = step(params, caches, tokens[:, t], jnp.int32(t), emb_t)
    _assert_serving_matches_forward(m.cfg, lg, logits[:, -1, :])


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(models, arch):
    m, params = models[arch]
    tokens, embeds = _inputs(m.cfg)
    logits, _ = m.forward(params, tokens, embeds=embeds)
    lg, caches = m.prefill(params, tokens, embeds=embeds)
    _assert_serving_matches_forward(m.cfg, lg, logits[:, -1, :])
    assert len(caches) >= m.cfg.n_layers


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_specs_no_alloc(arch):
    """FULL configs: spec construction + abstract params (no allocation)."""
    cfg = get_config(arch)
    m = Model(cfg)
    ab = m.abstract_params()
    n = m.n_params()
    assert n > 1e8  # every assigned arch is at least 100M params
    axes = m.param_axes()
    flat_ab = jax.tree.leaves(ab)
    flat_ax = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_ab) == len(flat_ax)
    for sds, ax in zip(flat_ab, flat_ax):
        assert len(sds.shape) == len(ax)
