"""Core Eidola tests: engine equivalence, paper-number reproduction, WTT and
Monitor Log invariants."""

import numpy as np
import pytest

from repro.core import (
    AddressMap,
    DirectoryMemory,
    Eidola,
    EidolaDeadlock,
    EngineKind,
    GaussianPerturb,
    MonitorLog,
    PeerDelayPerturb,
    RegisteredWrite,
    SimConfig,
    SyncPolicy,
    TraceBundle,
    WriteTrackingTable,
    run_gemv_allreduce,
)
from repro.core.workload import GemvAllReduceWorkload, make_gemv_allreduce_traces

ENGINES = (EngineKind.CYCLE, EngineKind.EVENT, EngineKind.VECTOR)


# ---------------------------------------------------------------------------
# engine equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sync", [SyncPolicy.SPIN, SyncPolicy.SYNCMON])
@pytest.mark.parametrize("delay_us", [0.0, 7.5, 25.0])
def test_engines_bit_identical(sync, delay_us):
    reports = {}
    for eng in ENGINES:
        cfg = SimConfig(sync=sync, engine=eng)
        reports[eng] = run_gemv_allreduce(cfg, delay_us * 1000.0)
    base = reports[EngineKind.CYCLE]
    for eng in ENGINES[1:]:
        r = reports[eng]
        assert r.flag_reads == base.flag_reads
        assert r.nonflag_reads == base.nonflag_reads
        assert r.traffic == base.traffic
        assert r.kernel_span_ns == pytest.approx(base.kernel_span_ns)


def test_engines_identical_under_perturbation():
    p = GaussianPerturb(seed=3, phase_sigma=0.05, write_sigma_ns=25.0)
    outs = []
    for eng in ENGINES:
        cfg = SimConfig(sync=SyncPolicy.SPIN, engine=eng)
        outs.append(run_gemv_allreduce(cfg, 12_000.0, perturb=p))
    assert outs[0].traffic == outs[1].traffic == outs[2].traffic


def test_engine_segments_agree():
    segs = []
    for eng in (EngineKind.EVENT, EngineKind.VECTOR):
        cfg = SimConfig(sync=SyncPolicy.SPIN, engine=eng)
        r = run_gemv_allreduce(cfg, 5_000.0)
        segs.append(
            sorted(
                (s.wg, s.phase, round(s.start_ns, 3), round(s.end_ns, 3))
                for s in r.segments
                if s.end_ns > s.start_ns
            )
        )
    assert segs[0] == segs[1]


# ---------------------------------------------------------------------------
# paper-number reproduction (Table 1 config)
# ---------------------------------------------------------------------------


def test_nonflag_reads_match_paper_66k():
    cfg = SimConfig()
    r = run_gemv_allreduce(cfg, 10_000.0, collect_segments=False)
    assert 60_000 <= r.nonflag_reads <= 70_000  # paper: "approximately 66K"
    # exact closed form: M*K/n/ (32/4) + reduce reads
    wl = GemvAllReduceWorkload(cfg)
    assert r.nonflag_reads == wl.expected_nonflag_reads() == 65_792


def test_spin_flag_reads_linear_in_delay():
    xs, ys = [], []
    for d_us in range(0, 41, 8):
        cfg = SimConfig(sync=SyncPolicy.SPIN, engine=EngineKind.EVENT)
        r = run_gemv_allreduce(cfg, d_us * 1000.0, collect_segments=False)
        xs.append(d_us)
        ys.append(r.flag_reads)
    fit = np.polyfit(xs, ys, 1)
    pred = np.polyval(fit, xs)
    ss_res = float(((np.array(ys) - pred) ** 2).sum())
    ss_tot = float(((np.array(ys) - np.mean(ys)) ** 2).sum())
    assert 1 - ss_res / ss_tot > 0.999
    assert fit[0] > 0  # grows with delay


def test_syncmon_flag_reads_bounded():
    vals = []
    for d_us in range(0, 41, 8):
        cfg = SimConfig(sync=SyncPolicy.SYNCMON, engine=EngineKind.EVENT)
        p = GaussianPerturb(seed=d_us * 7 + 1, write_sigma_ns=10.0)
        r = run_gemv_allreduce(cfg, d_us * 1000.0, perturb=p, collect_segments=False)
        vals.append(r.flag_reads)
    assert 700 <= min(vals) and max(vals) <= 800  # paper band: 728-788
    # and they do NOT scale with delay
    assert max(vals) - min(vals) < 200


def test_syncmon_preserves_nonflag_traffic():
    a = run_gemv_allreduce(
        SimConfig(sync=SyncPolicy.SPIN), 20_000.0, collect_segments=False
    )
    b = run_gemv_allreduce(
        SimConfig(sync=SyncPolicy.SYNCMON), 20_000.0, collect_segments=False
    )
    assert a.nonflag_reads == b.nonflag_reads


# ---------------------------------------------------------------------------
# WTT invariants
# ---------------------------------------------------------------------------


def test_wtt_pops_chronological_regardless_of_registration_order():
    wtt = WriteTrackingTable(clock_ghz=1.0)
    times = [50.0, 10.0, 30.0, 10.0, 90.0, 0.0]
    for i, t in enumerate(times):
        wtt.register(RegisteredWrite(wakeup_ns=t, addr=64 * i, data=i, seq=i))
    popped = []
    while not wtt.empty:
        c, group = wtt.pop_next_group()
        popped.extend((c, w.seq) for w in group)
    cycles = [c for c, _ in popped]
    assert cycles == sorted(cycles)
    # ties broken by registration order
    tie = [s for c, s in popped if c == 10]
    assert tie == sorted(tie)


def test_wtt_poll_is_o1_noop_before_wakeup():
    wtt = WriteTrackingTable(clock_ghz=1.0)
    wtt.register(RegisteredWrite(wakeup_ns=100.0, addr=0, data=1))
    assert wtt.poll(50) == []
    assert len(wtt) == 1
    due = wtt.poll(100)
    assert len(due) == 1 and wtt.empty


def test_wtt_ns_to_cycles_uses_clock():
    assert WriteTrackingTable(clock_ghz=1.5).ns_to_cycles(1000.0) == 1500
    assert WriteTrackingTable(clock_ghz=2.0).ns_to_cycles(3.0) == 6


# ---------------------------------------------------------------------------
# Monitor Log
# ---------------------------------------------------------------------------


def _mem():
    return DirectoryMemory(AddressMap(n_devices=4))


def test_monitor_masked_wake_hoare():
    mem = _mem()
    log = MonitorLog(mem, semantics="hoare", wake_latency_cycles=10)
    addr = mem.amap.flag_addr(1)
    e = log.monitor(addr, 8, wake_value=1)
    assert not log.mwait(e, wf_id=7, now_cycle=0)
    # a write with the WRONG value does not wake under hoare semantics
    mem.enact_xgmi_write(RegisteredWrite(wakeup_ns=0, addr=addr, data=2, size=8), 5)
    assert log.pop_wakes_until(10_000) == []
    mem.enact_xgmi_write(RegisteredWrite(wakeup_ns=0, addr=addr, data=1, size=8), 6)
    wakes = log.pop_wakes_until(10_000)
    assert wakes == [(7, 16)]


def test_monitor_mesa_wakes_on_any_touch():
    mem = _mem()
    log = MonitorLog(mem, semantics="mesa", wake_latency_cycles=4)
    addr = mem.amap.flag_addr(2)
    e = log.monitor(addr, 8, wake_value=1)
    assert not log.mwait(e, wf_id=3, now_cycle=0)
    mem.enact_xgmi_write(RegisteredWrite(wakeup_ns=0, addr=addr, data=99, size=8), 2)
    assert log.pop_wakes_until(10_000) == [(3, 6)]


def test_mwait_immediate_return_when_condition_holds():
    mem = _mem()
    log = MonitorLog(mem, semantics="mesa")
    addr = mem.amap.flag_addr(1)
    mem.enact_xgmi_write(RegisteredWrite(wakeup_ns=0, addr=addr, data=1, size=8), 0)
    e = log.monitor(addr, 8, wake_value=1)
    assert log.mwait(e, wf_id=1, now_cycle=5)  # returns immediately
    assert log.stats["immediate_mwait_returns"] == 1


def test_monitor_rejects_line_straddle():
    mem = _mem()
    log = MonitorLog(mem)
    with pytest.raises(ValueError):
        log.monitor(60, 8, 1)  # crosses the 64-byte line boundary


# ---------------------------------------------------------------------------
# misc core behaviour
# ---------------------------------------------------------------------------


def test_deadlock_detected_when_flags_missing():
    cfg = SimConfig(engine=EngineKind.EVENT)
    traces = TraceBundle()  # no writes at all
    with pytest.raises(EidolaDeadlock):
        Eidola(cfg, traces).run()


def test_trace_bundle_json_roundtrip(tmp_path):
    cfg = SimConfig()
    tr = make_gemv_allreduce_traces(cfg, [1000.0, 2000.0, 3000.0])
    path = tmp_path / "t.json"
    tr.save(str(path))
    tr2 = TraceBundle.load(str(path))
    assert len(tr2) == len(tr)
    assert [w.addr for w in tr2] == [w.addr for w in tr]
    assert tr2.meta["workload"] == "fused_gemv_allreduce"


def test_peer_delay_perturb_inflates_wait_phase():
    cfg = SimConfig(sync=SyncPolicy.SPIN, engine=EngineKind.EVENT)
    ideal = run_gemv_allreduce(cfg, 0.0)
    slow = run_gemv_allreduce(
        cfg, 0.0, perturb=PeerDelayPerturb({2: 30_000.0, 3: 30_000.0})
    )
    from repro.core.trace_render import phase_totals

    wait_ideal = phase_totals(ideal.segments).get("wait_flags", 0.0)
    wait_slow = phase_totals(slow.segments).get("wait_flags", 0.0)
    assert wait_slow > 10 * max(wait_ideal, 1.0)  # Fig. 2 non-ideality


def test_write_size_validation():
    with pytest.raises(ValueError):
        RegisteredWrite(wakeup_ns=0.0, addr=0, data=0, size=16)
    with pytest.raises(ValueError):
        RegisteredWrite(wakeup_ns=-1.0, addr=0, data=0)


# ---------------------------------------------------------------------------
# vector engine: multi-slot trace bundles (flag resolution via decode_flag)
# ---------------------------------------------------------------------------


def _multi_slot_setup():
    """Gemv scenario on a multi-slot AddressMap, trace carrying the slot-0
    peer flags PLUS extra flag writes in higher slots (ring-style bundles
    replayed on a shared symmetric-heap layout look exactly like this)."""
    from repro.core.scenarios.gemv_allreduce import GemvAllReduceScenario

    cfg = SimConfig()
    amap = AddressMap(n_devices=cfg.n_devices, flag_slots=4)
    sc = GemvAllReduceScenario(cfg, amap, flag_delays_ns=9_000.0)
    bundle = sc.traces()
    for g in range(1, cfg.n_devices):
        for slot in (1, 3):
            bundle.add(
                wakeup_ns=2_000.0 * g + 100.0 * slot,
                addr=amap.flag_addr(g, slot=slot),
                data=1,
                size=8,
                src=g,
            )
    return cfg, sc, bundle


def test_vector_engine_sees_multi_slot_flag_writes():
    """Regression: flag resolution linear-scanned amap.flag_addr(g) slot 0
    only; the higher-slot flag writes of a multi-slot bundle were invisible.
    decode_flag-based resolution (O(1), all slots) must keep the vector
    engine bit-identical to the event engine on such bundles."""
    cfg, sc, bundle = _multi_slot_setup()
    reports = {}
    for eng in (EngineKind.EVENT, EngineKind.VECTOR):
        from repro.core.scenarios.gemv_allreduce import GemvAllReduceScenario

        sc_run = GemvAllReduceScenario(
            cfg.with_(engine=eng), sc.amap, flag_delays_ns=9_000.0
        )
        reports[eng] = Eidola(
            cfg.with_(engine=eng), bundle, scenario=sc_run,
            collect_segments=False,
        ).run()
    a, b = reports[EngineKind.EVENT], reports[EngineKind.VECTOR]
    assert a.traffic == b.traffic
    assert a.flag_reads == b.flag_reads
    assert b.wtt_enacted == len(bundle)  # extra slots enacted, not dropped


def test_vector_engine_missing_slot0_flags_names_available_slots():
    """A bundle whose flags all sit in slots > 0 deadlocks the gemv waits
    (they poll slot 0) — but the report must name the flags the bundle DOES
    carry instead of claiming there are no flag writes at all."""
    from repro.core.scenarios.gemv_allreduce import GemvAllReduceScenario

    cfg = SimConfig(engine=EngineKind.VECTOR)
    amap = AddressMap(n_devices=cfg.n_devices, flag_slots=4)
    sc = GemvAllReduceScenario(cfg, amap, flag_delays_ns=9_000.0)
    bundle = TraceBundle()
    for g in range(1, cfg.n_devices):
        bundle.add(
            wakeup_ns=2_000.0 * g,
            addr=amap.flag_addr(g, slot=2),
            data=1,
            size=8,
            src=g,
        )
    with pytest.raises(EidolaDeadlock) as ei:
        Eidola(cfg, bundle, scenario=sc, collect_segments=False).run()
    msg = str(ei.value)
    assert "slot-0" in msg
    assert "(1, 2)" in msg  # the bundle's actual (src, slot) flags are named
