"""Hypothesis property tests on system invariants."""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    AddressMap,
    DirectoryMemory,
    MonitorLog,
    RegisteredWrite,
    SimConfig,
    SyncPolicy,
    EngineKind,
    WriteTrackingTable,
    run_gemv_allreduce,
)
from repro.core.hlo_analyzer import analyze_hlo
from repro.distributed.sharding import DEFAULT_RULES, resolve_spec

# ---------------------------------------------------------------------------
# WTT invariants
# ---------------------------------------------------------------------------


@given(
    times=st.lists(
        st.floats(min_value=0, max_value=1e6, allow_nan=False), min_size=1,
        max_size=64,
    ),
    clock=st.sampled_from([0.94, 1.0, 1.5, 2.0]),
)
@settings(max_examples=60, deadline=None)
def test_wtt_pops_are_chronological(times, clock):
    wtt = WriteTrackingTable(clock_ghz=clock)
    for i, t in enumerate(times):
        wtt.register(RegisteredWrite(wakeup_ns=t, addr=64 * i, data=i, seq=i))
    popped = []
    while not wtt.empty:
        c, group = wtt.pop_next_group()
        assert group, "pop of nonempty WTT must return writes"
        popped.append((c, [w.seq for w in group]))
    cycles = [c for c, _ in popped]
    assert cycles == sorted(cycles)
    assert sorted(s for _, seqs in popped for s in seqs) == sorted(
        range(len(times))
    )


@given(
    times=st.lists(
        st.integers(min_value=0, max_value=10_000), min_size=1, max_size=64
    ),
    poll_at=st.integers(min_value=0, max_value=12_000),
)
@settings(max_examples=60, deadline=None)
def test_wtt_poll_returns_exactly_due_writes(times, poll_at):
    wtt = WriteTrackingTable(clock_ghz=1.0)
    for i, t in enumerate(times):
        wtt.register(RegisteredWrite(wakeup_ns=float(t), addr=0, data=i, seq=i))
    due = wtt.poll(poll_at)
    assert {w.seq for w in due} == {
        i for i, t in enumerate(times) if t <= poll_at
    }
    assert len(wtt) == sum(1 for t in times if t > poll_at)


# ---------------------------------------------------------------------------
# Monitor Log: a wake fires iff the masked compare matches (hoare)
# ---------------------------------------------------------------------------


@given(
    wake_value=st.integers(min_value=0, max_value=2**32 - 1),
    written=st.integers(min_value=0, max_value=2**32 - 1),
    size=st.sampled_from([1, 2, 4, 8]),
)
@settings(max_examples=60, deadline=None)
def test_monitor_hoare_masked_compare(wake_value, written, size):
    mem = DirectoryMemory(AddressMap(n_devices=4))
    log = MonitorLog(mem, semantics="hoare", wake_latency_cycles=1)
    addr = mem.amap.flag_addr(1)
    e = log.monitor(addr, size, wake_value)
    immediate = log.mwait(e, wf_id=0, now_cycle=0)
    if immediate:
        # condition already held (memory zero-initialized, wake value 0):
        # the wavefront never descheduled, so no wake can fire
        assert mem.peek(addr, size) == (wake_value & ((1 << (8 * size)) - 1))
        e.waiting_wfs.add(0)  # arm anyway to exercise the wake path below
    mem.enact_xgmi_write(
        RegisteredWrite(wakeup_ns=0, addr=addr, data=written, size=size), 10
    )
    wakes = log.pop_wakes_until(10_000)
    should_wake = (written & ((1 << (8 * size)) - 1)) == (
        wake_value & ((1 << (8 * size)) - 1)
    )
    assert bool(wakes) == should_wake


# ---------------------------------------------------------------------------
# engine equivalence as a property over delays
# ---------------------------------------------------------------------------


@given(
    delays=st.lists(
        st.floats(min_value=0, max_value=30_000, allow_nan=False),
        min_size=3, max_size=3,
    ),
    sync=st.sampled_from([SyncPolicy.SPIN, SyncPolicy.SYNCMON]),
)
@settings(max_examples=12, deadline=None)
def test_event_and_vector_engines_agree(delays, sync):
    out = {}
    for eng in (EngineKind.EVENT, EngineKind.VECTOR):
        cfg = SimConfig(sync=sync, engine=eng, workgroups=32, M=32, K=512)
        r = run_gemv_allreduce(cfg, delays, collect_segments=False)
        out[eng] = (r.flag_reads, r.nonflag_reads, r.kernel_span_ns)
    assert out[EngineKind.EVENT] == out[EngineKind.VECTOR]


# ---------------------------------------------------------------------------
# sharding rules: resolved specs always divide the dims they shard
# ---------------------------------------------------------------------------


@given(
    dims=st.lists(st.integers(min_value=1, max_value=4096), min_size=1,
                  max_size=4),
    axes=st.lists(
        st.sampled_from(["embed", "heads", "kv", "mlp", "vocab", "experts",
                         None]),
        min_size=1, max_size=4,
    ),
)
@settings(max_examples=80, deadline=None)
def test_resolve_spec_divisibility(dims, axes):
    import os

    n = min(len(dims), len(axes))
    dims, axes = dims[:n], axes[:n]
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # a fake 4x4 mesh is enough to test the table logic; use real mesh sizes
    spec = resolve_spec(dims, axes, DEFAULT_RULES, mesh, path="t")
    # every sharded dim must divide by its mesh axis size
    for d, part in zip(dims, tuple(spec)):
        if part is not None:
            assert d % mesh.shape[part] == 0


# ---------------------------------------------------------------------------
# HLO analyzer: while-loop multipliers on synthetic modules
# ---------------------------------------------------------------------------


@given(trip=st.integers(min_value=2, max_value=500))
@settings(max_examples=20, deadline=None)
def test_analyzer_scales_with_trip_count(trip):
    hlo = f"""
%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {{
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(f32[8,8] %x, f32[8,8] %x), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %d)
}}

%cond (p: (s32[], f32[8,8])) -> pred[] {{
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant({trip})
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {{
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}}
"""
    mod = analyze_hlo(hlo)
    assert mod.max_while_trip() == trip
    assert mod.dot_flops() == trip * 2 * 8 * 8 * 8


# ---------------------------------------------------------------------------
# tiered lockstep: group-uniform solving over multi-tier fabrics
# ---------------------------------------------------------------------------

from repro.core.scenario import get_scenario, simulate  # noqa: E402

_TIERED_KEYS = (
    "flag_reads", "nonflag_reads", "local_writes", "xgmi_writes_in",
    "xgmi_writes_out", "xgmi_bytes_in", "xgmi_bytes_out", "read_bytes",
    "write_bytes",
)


def _tiered_sig(r):
    return (
        tuple(r.traffic.get(k) for k in _TIERED_KEYS),
        r.sim_cycles,
        tuple(sorted((d, tuple(sorted(t.items()))) for d, t in
                     r.per_device.items())),
        (r.wtt_registered, r.wtt_enacted),
        tuple(sorted((k, v) for k, v in r.meta["fabric"].items()
                     if isinstance(v, int))),
    )


@given(
    name=st.sampled_from([
        "ring_allreduce", "all_to_all", "hierarchical_allreduce",
        "pipeline_p2p",
    ]),
    fabric=st.sampled_from(["two_tier", "fat_tree", "rail_optimized"]),
    dpn=st.sampled_from([2, 3, 4]),
    nodes=st.integers(min_value=2, max_value=8),
)
@settings(max_examples=14, deadline=None)
def test_tiered_lockstep_matches_timeline(name, fabric, dpn, nodes):
    n = dpn * nodes
    if not 4 <= n <= 33:
        return
    cfg = SimConfig(engine=EngineKind.EVENT, workgroups=4).with_devices(n)
    kw = dict(devices=n, closed_loop=True, collect_segments=False,
              devices_per_node=dpn, fabric=fabric)
    fast = simulate(name, cfg, **kw)  # lockstep auto-selects
    slow = simulate(name, cfg, lockstep=False, **kw)
    if name == "pipeline_p2p":
        # cross-rank pipelined chains fall back with a group-level blame
        assert "group" in fast.meta["lockstep_reason"]
        assert fast.meta["program_stats"]["lockstep"] is False
    else:
        assert fast.meta["lockstep_reason"] == "engaged", (
            name, fabric, n, dpn, fast.meta["lockstep_reason"],
        )
        assert fast.meta["program_stats"]["lockstep"] is True
    assert _tiered_sig(fast) == _tiered_sig(slow), (name, fabric, n, dpn)
