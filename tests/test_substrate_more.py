"""Additional substrate coverage: optimizer behaviour, HLO capture parsing,
timeline exports, MoE capacity drops, predictor math, topology algebra."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_capture import (
    CollectiveOp,
    collective_bytes,
    parse_collectives,
    schedule_to_trace,
)
from repro.core.predictor import predict_step, roofline
from repro.core.topology import Topology, V5E
from repro.core.trace_render import ascii_timeline, phase_totals, to_chrome_trace, to_csv
from repro.core import SimConfig, SyncPolicy, EngineKind, run_gemv_allreduce
from repro.optim import AdamWConfig, adamw_init, adamw_step, cosine_lr

# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_ratio=1.0, master_fp32=True)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw_init(params, cfg)
    for _ in range(150):
        g = {"w": 2.0 * params["w"]}  # d/dw ||w||^2
        params, state, metrics = adamw_step(params, g, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1
    assert float(metrics["grad_norm"]) < 1.0


def test_cosine_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(cosine_lr(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5, abs=0.01)
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] == pytest.approx(0.1, abs=0.01)


def test_adamw_bf16_params_fp32_master_roundtrip():
    cfg = AdamWConfig(lr=1e-3, master_fp32=True)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 0.5, jnp.bfloat16)}
    p2, s2, _ = adamw_step(params, g, state, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    # master tracks higher-precision value
    assert float(s2["master"]["w"][0]) != 1.0


# ---------------------------------------------------------------------------
# HLO capture parsing
# ---------------------------------------------------------------------------

HLO_SNIPPET = """
  %all-reduce.2 = f32[8,128]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %ag = bf16[4096,512]{1,0} all-gather(%p0), channel_id=2, replica_groups=[16,32]<=[512], dimensions={0}
  %rs = f32[64]{0} reduce-scatter(%big), channel_id=3, replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = bf16[256]{0} collective-permute(%x), channel_id=4, source_target_pairs={{0,1},{1,0}}
"""


def test_parse_collectives_kinds_and_groups():
    ops = parse_collectives(HLO_SNIPPET)
    kinds = {o.kind: o for o in ops}
    assert kinds["all-reduce"].group_size == 4
    assert kinds["all-reduce"].result_bytes == 8 * 128 * 4
    assert kinds["all-gather"].group_size == 32
    # all-gather operand is the shard
    assert kinds["all-gather"].operand_bytes == 4096 * 512 * 2 // 32
    assert kinds["reduce-scatter"].group_size == 4
    assert kinds["reduce-scatter"].operand_bytes == 64 * 4 * 4
    assert collective_bytes(ops) > 0


def test_schedule_to_trace_replayable():
    ops = [CollectiveOp("all-reduce", 2**20, 2**20, 16),
           CollectiveOp("all-gather", 2**18, 2**14, 16)]
    topo = Topology((16, 16), ("data", "model"))
    tr = schedule_to_trace(ops, topo, compute_gap_ns=100.0)
    assert len(tr) > 3
    from repro.core import Eidola

    r = Eidola(SimConfig(engine=EngineKind.EVENT, sync=SyncPolicy.SYNCMON), tr).run()
    assert r.flag_reads > 0 and r.kernel_span_ns > 0


# ---------------------------------------------------------------------------
# topology / predictor algebra
# ---------------------------------------------------------------------------


def test_ring_allreduce_cost_algebra():
    topo = Topology((16, 16), ("data", "model"))
    c = topo.collective("all-reduce", 100 * 2**20, "model")
    assert c.steps == 30  # 2(k-1)
    # 2B(k-1)/k on the link
    assert c.link_bytes == 2 * 100 * 2**20 * 15 // 16
    c2 = topo.collective("collective-permute", 2**20, "data")
    assert c2.steps == 1 and c2.link_bytes == 2**20


def test_pod_axis_uses_dci_bandwidth():
    topo = Topology((2, 16, 16), ("pod", "data", "model"))
    # same bytes over one hop: the inter-pod fabric is slower per link
    t_ici = topo.collective("collective-permute", 2**26, "model").time_s
    t_dci = topo.collective("collective-permute", 2**26, "pod").time_s
    assert t_dci > t_ici


def test_roofline_dominant_term():
    topo = Topology((16, 16), ("data", "model"))
    t = roofline(
        arch="x", shape="y", mesh="single", topo=topo,
        hlo_flops_per_device=1e12, hlo_bytes_per_device=1e12,
        collective_bytes_per_device=10**9, model_flops_total=1e12 * 256 * 0.5,
    )
    assert t.dominant == "memory"  # 1e12/819e9 > 1e12/197e12, 1e9/50e9
    assert 0 < t.roofline_fraction() < 1
    p = predict_step(t, topo)
    assert p.no_overlap_s >= p.full_overlap_s


# ---------------------------------------------------------------------------
# timeline exports
# ---------------------------------------------------------------------------


def test_timeline_exports():
    r = run_gemv_allreduce(SimConfig(engine=EngineKind.EVENT), 2_000.0)
    tr = to_chrome_trace(r.segments)
    obj = json.loads(tr)
    assert len(obj["traceEvents"]) > 100
    csv = to_csv(r.segments)
    assert csv.splitlines()[0] == "wg,phase,start_ns,end_ns"
    art = ascii_timeline(r.segments, max_rows=4)
    assert "wg" in art
    totals = phase_totals(r.segments)
    assert totals.get("remote_tiles", 0) > 0


# ---------------------------------------------------------------------------
# MoE capacity drops
# ---------------------------------------------------------------------------


def test_moe_ep_capacity_drops_counted():
    import os
    import subprocess
    import sys

    script = """
import jax, jax.numpy as jnp
from repro.models.common import ModelConfig, materialize
from repro.models.moe import moe_specs
from repro.models.moe_ep import moe_apply_ep
cfg = ModelConfig(n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_ff=32,
                  vocab=64, n_experts=8, experts_per_token=4,
                  capacity_factor=0.25, param_dtype=jnp.float32)
p = materialize(moe_specs(cfg), jax.random.PRNGKey(0))
mesh = jax.make_mesh((1, 4), ("data", "model"))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16), jnp.float32)
y, aux = jax.jit(lambda p, x: moe_apply_ep(cfg, p, x, mesh))(p, x)
assert float(aux["moe_dropped"]) > 0, "tiny capacity must drop tokens"
assert bool(jnp.isfinite(y).all())
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
