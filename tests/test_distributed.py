"""Multi-device distribution tests.

These need ``--xla_force_host_platform_device_count`` set BEFORE jax
initializes, so each test runs an inline script in a subprocess with the
flag in its environment (the same mechanism dryrun.py uses in-process).
"""

import os
import subprocess
import sys

import pytest

# model-forward-dominated: runs in the separate slow CI job, not the fast
# simulator suite
pytestmark = pytest.mark.slow

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_devices(script: str, n_devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_fused_gemv_allreduce_equals_psum():
    run_devices(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.collectives import psum_matmul, fused_gemv_allreduce
mesh = jax.make_mesh((8,), ("model",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 256), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (256, 64), jnp.float32) * 0.05
y1 = jax.jit(psum_matmul(mesh))(x, w)
y2 = jax.jit(fused_gemv_allreduce(mesh))(x, w)
np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
print("OK")
"""
    )


def test_ep_moe_matches_local_oracle_and_grads():
    run_devices(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.models.common import ModelConfig, materialize
from repro.models.moe import moe_apply, moe_specs
from repro.models.moe_ep import moe_apply_ep

cfg = ModelConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=48,
                  vocab=64, n_experts=8, experts_per_token=2,
                  n_shared_experts=1, capacity_factor=4.0,
                  param_dtype=jnp.float32)
p = materialize(moe_specs(cfg), jax.random.PRNGKey(0))
mesh = jax.make_mesh((2, 4), ("data", "model"))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32) * 0.5
y_local, _ = moe_apply(cfg, p, x)
y_ep, _ = jax.jit(lambda p, x: moe_apply_ep(cfg, p, x, mesh))(p, x)
np.testing.assert_allclose(y_ep, y_local, rtol=1e-4, atol=1e-4)
# decode-sized input exercises the gather path
x1 = x[:, :1, :]
y1_l, _ = moe_apply(cfg, p, x1)
y1_e, _ = jax.jit(lambda p, x: moe_apply_ep(cfg, p, x, mesh))(p, x1)
np.testing.assert_allclose(y1_e, y1_l, rtol=1e-4, atol=1e-4)
g_ep = jax.grad(lambda p: jnp.sum(moe_apply_ep(cfg, p, x, mesh)[0]**2))(p)
g_lo = jax.grad(lambda p: jnp.sum(moe_apply(cfg, p, x)[0]**2))(p)
for k in g_ep:
    np.testing.assert_allclose(g_ep[k], g_lo[k], rtol=1e-3, atol=1e-4)
print("OK")
"""
    )


def test_sharded_train_step_matches_single_device():
    """The same init + batch must give the same loss on (1,1) and (2,4)."""
    out = run_devices(
        """
import jax, jax.numpy as jnp
from repro.models import Model, ModelConfig
from repro.training import TrainConfig, build_train_step
from repro.optim import AdamWConfig, adamw_init
import numpy as np

cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                  vocab=128, param_dtype=jnp.float32)
tok = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128)
lab = jnp.roll(tok, -1, axis=1)
losses = []
for dims in ((1, 1), (2, 4)):
    mesh = jax.make_mesh(dims, ("data", "model"))
    model = Model(cfg, mesh=mesh)
    tcfg = TrainConfig(optim=AdamWConfig(lr=1e-3), donate_state=False)
    step, sh, fb = build_train_step(model, mesh, tcfg)
    with mesh:
        params = jax.jit(model.init, out_shardings=sh["params"])(
            jax.random.PRNGKey(0))
        state = jax.jit(lambda p: adamw_init(p, tcfg.optim),
                        out_shardings=sh["state"])(params)
        p2, s2, metrics = step(params, state, tok, lab)
    losses.append(float(metrics["loss"]))
print("losses", losses)
assert abs(losses[0] - losses[1]) < 1e-3, losses
print("OK")
"""
    )
    assert "OK" in out


def test_sharded_train_step_bf16_across_mesh_shapes():
    """The fp32 cross-mesh determinism above, in bf16: the same init + batch
    must give matching losses on (1,1), (2,4), and (4,2) meshes with bf16
    params (ROADMAP open item — the partitionable-threefry fix was only
    exercised at fp32).  bf16 accumulates rounding differently per sharding,
    so the tolerance is bf16-sized rather than exact."""
    out = run_devices(
        """
import jax, jax.numpy as jnp
from repro.models import Model, ModelConfig
from repro.training import TrainConfig, build_train_step
from repro.optim import AdamWConfig, adamw_init

cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                  vocab=128, param_dtype=jnp.bfloat16)
tok = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128)
lab = jnp.roll(tok, -1, axis=1)
losses = []
for dims in ((1, 1), (2, 4), (4, 2)):
    mesh = jax.make_mesh(dims, ("data", "model"))
    model = Model(cfg, mesh=mesh)
    tcfg = TrainConfig(optim=AdamWConfig(lr=1e-3), donate_state=False)
    step, sh, fb = build_train_step(model, mesh, tcfg)
    with mesh:
        params = jax.jit(model.init, out_shardings=sh["params"])(
            jax.random.PRNGKey(0))
        assert all(p.dtype == jnp.bfloat16 for p in jax.tree.leaves(params))
        state = jax.jit(lambda p: adamw_init(p, tcfg.optim),
                        out_shardings=sh["state"])(params)
        p2, s2, metrics = step(params, state, tok, lab)
    losses.append(float(metrics["loss"]))
print("losses", losses)
spread = max(losses) - min(losses)
assert spread < 0.05, (losses, spread)
print("OK")
"""
    )
    assert "OK" in out


def test_indivisible_dims_fall_back_to_replication():
    """minicpm3's vocab (73448) is not divisible by a 16-way model axis:
    those tensors must fall back to replication (recorded), not crash —
    and a reduced model still runs under resolved shardings."""
    run_devices(
        """
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.models import Model
from repro.distributed import param_shardings, DEFAULT_RULES

# FULL config, abstract only (no allocation): vocab 73448 % 16 != 0
cfg = get_config("minicpm3-4b")
m = Model(cfg)
mesh16 = jax.make_mesh((1, 16), ("data", "model"))
sh, fallbacks = param_shardings(m.param_axes(), m.abstract_params(), mesh16,
                                DEFAULT_RULES)
assert any("replicated" in f for f in fallbacks), fallbacks

# and a reduced model actually runs under resolved shardings
cfg_r = reduced(get_config("gemma3-1b"))
mr = Model(cfg_r)
mesh = jax.make_mesh((2, 4), ("data", "model"))
sh_r, _ = param_shardings(mr.param_axes(), mr.abstract_params(), mesh,
                          DEFAULT_RULES)
params = jax.jit(mr.init, out_shardings=sh_r)(jax.random.PRNGKey(0))
tok = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg_r.vocab)
logits, _ = jax.jit(lambda p, t: mr.forward(p, t))(params, tok)
assert logits.shape == (4, 16, cfg_r.vocab)
print("OK")
""",
        n_devices=16,
    )


def test_dryrun_single_cell_tiny_mesh():
    """run_cell end-to-end on a 2x2 mesh inside the subprocess."""
    out = run_devices(
        """
import os
os.environ.setdefault("XLA_FLAGS", "")
from repro.launch.dryrun import run_cell
rec = run_cell("xlstm-125m", "train_4k", "2x2", {"remat": "full"},
               verbose=False)
assert rec["status"] == "ok", rec.get("error")
assert rec["flops_per_device"] > 0
assert rec["collective_bytes_per_device"] > 0
assert rec["max_scan_trip"] >= 1
print("OK")
""",
        n_devices=4,
    )
    assert "OK" in out


def test_compressed_psum_accuracy():
    run_devices(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import compressed_psum
from repro.distributed.compat import SHARD_MAP_NO_CHECK, shard_map
mesh = jax.make_mesh((8,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 64), jnp.float32)
fn = shard_map(lambda x: compressed_psum(x, "data"), mesh=mesh,
               in_specs=P("data"), out_specs=P("data"), **SHARD_MAP_NO_CHECK)
out = jax.jit(fn)(g)
exact = np.broadcast_to(np.asarray(g).sum(0, keepdims=True), (8, 64))
# int8 quantization bound: n_shards * max|g| / 127 (elementwise absolute)
bound = 8 * float(np.abs(np.asarray(g)).max()) / 127.0
err = np.abs(np.asarray(out) - exact).max()
assert err < bound, (err, bound)
print("OK")
"""
    )


def test_pipeline_parallel_matches_sequential():
    run_devices(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply, stack_stage_params
mesh = jax.make_mesh((4,), ("pipe",))
L, d = 8, 16
W = jax.random.normal(jax.random.PRNGKey(0), (L, d, d), jnp.float32) * 0.25
b = jax.random.normal(jax.random.PRNGKey(1), (L, d), jnp.float32) * 0.1
layers = {"w": W, "b": b}
def layer_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])
x = jax.random.normal(jax.random.PRNGKey(2), (8, d), jnp.float32)
ref = x
for i in range(L):
    ref = layer_fn(jax.tree.map(lambda a: a[i], layers), ref)
apply = pipeline_apply(mesh, layer_fn, n_micro=4)
out = jax.jit(apply)(stack_stage_params(layers, 4), x)
np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
# grads flow through collective_permute's transpose (reverse pipeline)
g = jax.grad(lambda sp: jnp.sum(apply(sp, x)**2))(stack_stage_params(layers, 4))
assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
print("OK")
""",
        n_devices=4,
    )
