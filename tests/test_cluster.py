"""Closed-loop multi-device simulation tests: cycle/event bit-identity at
--devices 4 for every registered scenario, open-loop replay equivalence at
zero perturbation, cross-device perturbation propagation, fabric routing and
contention, actionable deadlock diagnostics, and the per-device Report
breakdown."""

import pytest

from repro.core import (
    Cluster,
    Eidola,
    EidolaDeadlock,
    EmitOp,
    EngineKind,
    FabricModel,
    SimConfig,
    SyncPolicy,
    TraceBundle,
    get_scenario,
    list_scenarios,
    simulate,
)
from repro.core.scenarios.ring_allreduce import RingAllReduceScenario

FAST = SimConfig(workgroups=12, n_cus=4)

CLOSED_LOOP = (
    "ring_allreduce",
    "all_to_all",
    "pipeline_p2p",
    "hierarchical_allreduce",
)


def _segments_key(report):
    return sorted(
        (s.device, s.wg, s.phase, round(s.start_ns, 6), round(s.end_ns, 6))
        for s in report.segments
    )


def _wait_ends(report, device):
    return [
        s.end_ns
        for s in report.segments
        if s.device == device and s.phase == "wait_flags"
    ]


# ---------------------------------------------------------------------------
# engine bit-identity in the closed loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(set(list_scenarios())))
@pytest.mark.parametrize("sync", [SyncPolicy.SPIN, SyncPolicy.SYNCMON])
def test_cycle_event_bit_identical_at_4_devices(name, sync):
    """Every registered scenario at --devices 4: closed loop where supported,
    open loop otherwise — cycle and event engines must agree bit-for-bit."""
    params = {"closed_loop": True} if name in CLOSED_LOOP else {}
    reports = {}
    for eng in (EngineKind.CYCLE, EngineKind.EVENT):
        cfg = FAST.with_(sync=sync, engine=eng)
        reports[eng] = simulate(name, cfg, devices=4, **params)
    a, b = reports[EngineKind.CYCLE], reports[EngineKind.EVENT]
    assert a.traffic == b.traffic
    assert a.per_device == b.per_device
    assert a.kernel_span_ns == pytest.approx(b.kernel_span_ns)
    assert _segments_key(a) == _segments_key(b)
    assert a.monitor_stats == b.monitor_stats


def test_ring_8_devices_closed_loop_both_engines():
    """The acceptance case: devices=8 closed loop, identical traffic and
    timelines under both engines."""
    reports = {}
    for eng in (EngineKind.CYCLE, EngineKind.EVENT):
        cfg = FAST.with_(engine=eng)
        reports[eng] = simulate(
            "ring_allreduce", cfg, devices=8, closed_loop=True
        )
    a, b = reports[EngineKind.CYCLE], reports[EngineKind.EVENT]
    assert a.n_devices == b.n_devices == 8
    assert a.closed_loop and b.closed_loop
    assert a.traffic == b.traffic
    assert _segments_key(a) == _segments_key(b)
    # every rank of a symmetric ring sees identical traffic
    assert len(a.per_device) == 8
    assert len({tuple(sorted(t.items())) for t in a.per_device.values()}) == 1


def test_open_loop_gemv_preserved_alongside_clusters():
    """The degenerate case: open-loop gemv_allreduce still reproduces the
    paper's exact non-flag read count with the cluster machinery in place."""
    r = simulate(
        "gemv_allreduce",
        SimConfig(engine=EngineKind.EVENT),
        flag_delays_ns=10_000.0,
        collect_segments=False,
    )
    assert r.nonflag_reads == 65_792
    assert not r.closed_loop and r.n_devices == 1
    assert r.per_device[0]["nonflag_reads"] == 65_792


def test_gemv_has_no_closed_loop_mode():
    with pytest.raises(TypeError):
        simulate("gemv_allreduce", FAST, closed_loop=True)


# ---------------------------------------------------------------------------
# zero perturbation: closed loop == open-loop replay of the emergent schedule
# ---------------------------------------------------------------------------


def test_closed_loop_ring_matches_open_loop_replay_of_its_schedule():
    """Freeze the closed loop's emergent flag schedule into a trace bundle;
    open-loop replay of that bundle must reproduce device 0's reads and wait
    timeline exactly (the eidolon is just a device whose program replays a
    bundle)."""
    cfg = FAST.with_(engine=EngineKind.EVENT, include_data_writes=False)
    sc = RingAllReduceScenario(cfg, closed_loop=True)
    cluster = Cluster(cfg, sc)
    closed = cluster.run()
    arrivals = cluster.nodes[0].target.flag_set_cycle
    assert len(arrivals) == sc.steps

    bundle = TraceBundle(meta={"scenario": "ring_allreduce"})
    for addr, cyc in sorted(arrivals.items(), key=lambda kv: kv[1]):
        bundle.add(
            wakeup_ns=cfg.cycles_to_ns(cyc) - cfg.xgmi_enact_latency_ns,
            addr=addr,
            data=1,
            size=8,
            src=cfg.n_devices - 1,
        )
    open_sc = RingAllReduceScenario(cfg)
    replay = Eidola(cfg, bundle, scenario=open_sc).run()

    c0, o0 = closed.per_device[0], replay.per_device[0]
    assert c0["flag_reads"] == o0["flag_reads"]
    assert c0["nonflag_reads"] == o0["nonflag_reads"]
    closed_waits = sorted(
        (s.wg, round(s.start_ns, 6), round(s.end_ns, 6))
        for s in closed.segments
        if s.device == 0 and s.phase == "wait_flags"
    )
    replay_waits = sorted(
        (s.wg, round(s.start_ns, 6), round(s.end_ns, 6))
        for s in replay.segments
        if s.phase == "wait_flags"
    )
    assert closed_waits == replay_waits


# ---------------------------------------------------------------------------
# perturbation propagation (the point of the closed loop)
# ---------------------------------------------------------------------------


class _SlowReduce:
    """Deterministically stretch one rank's ring_reduce phases."""

    def __init__(self, factor=16):
        self.factor = factor

    def scale_phase(self, wg, name, base_cycles):
        return base_cycles * self.factor if name == "ring_reduce" else base_cycles

    def jitter_write(self, w):
        return w


def test_perturbing_one_rank_shifts_downstream_wait_segments():
    cfg = FAST.with_(engine=EngineKind.EVENT)
    base = simulate("ring_allreduce", cfg, devices=4, closed_loop=True)
    pert = simulate(
        "ring_allreduce",
        cfg,
        devices=4,
        closed_loop=True,
        perturb={1: _SlowReduce()},
    )
    # flags now arrive later downstream: every other rank's wait segments
    # shift to later wall-clock times, and the whole kernel stretches
    for dev in (2, 3, 0):
        assert sum(_wait_ends(pert, dev)) > sum(_wait_ends(base, dev)), dev
    assert pert.kernel_span_ns > base.kernel_span_ns
    # rank 2 is directly downstream of the slow rank: its *last* reduce input
    # is strictly delayed
    assert max(_wait_ends(pert, 2)) > max(_wait_ends(base, 2))


def test_propagation_identical_across_engines():
    reports = {}
    for eng in (EngineKind.CYCLE, EngineKind.EVENT):
        cfg = FAST.with_(engine=eng)
        reports[eng] = simulate(
            "ring_allreduce",
            cfg,
            devices=4,
            closed_loop=True,
            perturb={1: _SlowReduce()},
        )
    a, b = reports[EngineKind.CYCLE], reports[EngineKind.EVENT]
    assert a.traffic == b.traffic
    assert _segments_key(a) == _segments_key(b)


def test_write_jitter_deterministic_across_engines():
    """Gaussian jitter on emitted writes is keyed by (src, seq); the global
    emission order is engine-invariant, so jittered closed-loop runs must
    still match bit-for-bit."""
    from repro.core import GaussianPerturb

    reports = {}
    for eng in (EngineKind.CYCLE, EngineKind.EVENT):
        cfg = FAST.with_(engine=eng)
        reports[eng] = simulate(
            "ring_allreduce",
            cfg,
            devices=4,
            closed_loop=True,
            perturb=GaussianPerturb(seed=7, phase_sigma=0.1,
                                    write_sigma_ns=300.0),
        )
    a, b = reports[EngineKind.CYCLE], reports[EngineKind.EVENT]
    assert a.traffic == b.traffic
    assert _segments_key(a) == _segments_key(b)


# ---------------------------------------------------------------------------
# fabric model
# ---------------------------------------------------------------------------


def test_fabric_ring_routing():
    f = FabricModel(6, hop_latency_ns=100.0, link_bw_bytes_per_ns=1.0)
    assert f.route(0, 1) == (1, +1)
    assert f.route(0, 5) == (1, -1)
    assert f.route(1, 4) == (3, +1)  # tie broken toward ascending ids
    with pytest.raises(ValueError):
        f.route(0, 0)
    with pytest.raises(ValueError):
        f.route(0, 6)


def test_fabric_serialization_and_contention():
    f = FabricModel(4, hop_latency_ns=100.0, link_bw_bytes_per_ns=2.0)
    # 200 bytes at 2 B/ns = 100 ns serialization + 1 hop latency
    assert f.transfer(0, 1, 200, issue_ns=0.0) == pytest.approx(200.0)
    # same egress port still busy until 100ns: second burst queues behind it
    assert f.transfer(0, 1, 200, issue_ns=0.0) == pytest.approx(300.0)
    # opposite direction uses the other port: no queueing
    assert f.transfer(0, 3, 200, issue_ns=0.0) == pytest.approx(200.0)
    assert f.stats["messages"] == 3
    assert f.stats["queued_ns"] == pytest.approx(100.0)


def test_emitop_validation():
    with pytest.raises(ValueError):
        EmitOp(dst=-1)
    with pytest.raises(ValueError):
        EmitOp(dst=0, size=16)
    with pytest.raises(ValueError):
        EmitOp(dst=0, coalesce="sometimes")


def test_address_map_decode_flag_round_trip():
    from repro.core import AddressMap

    amap = AddressMap(n_devices=4, flag_slots=6)
    for d in range(4):
        for s in range(6):
            assert amap.decode_flag(amap.flag_addr(d, slot=s)) == (d, s)
    assert amap.decode_flag(amap.data_base) is None
    assert amap.decode_flag(amap.flag_addr(1) + 4) is None  # misaligned


# ---------------------------------------------------------------------------
# actionable deadlock diagnostics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("eng", [EngineKind.CYCLE, EngineKind.EVENT])
def test_deadlock_message_names_scenario_wgs_and_flags(eng):
    cfg = FAST.with_(engine=eng)
    sc = RingAllReduceScenario(cfg)
    with pytest.raises(EidolaDeadlock) as ei:
        Eidola(cfg, TraceBundle(), scenario=sc).run()  # no flag writes at all
    msg = str(ei.value)
    assert "'ring_allreduce'" in msg
    assert "device 0" in msg
    assert "wg 0-11" in msg  # all 12 workgroups, range-compressed
    expected_addr = sc.amap.flag_addr(cfg.n_devices - 1, slot=0)
    assert f"0x{expected_addr:x}" in msg
    assert f"src_device={cfg.n_devices - 1}" in msg
    assert "slot=0" in msg


# ---------------------------------------------------------------------------
# report plumbing
# ---------------------------------------------------------------------------


def test_per_device_breakdown_sums_to_aggregate():
    r = simulate(
        "all_to_all",
        FAST.with_(engine=EngineKind.EVENT),
        devices=4,
        closed_loop=True,
        collect_segments=False,
    )
    assert set(r.per_device) == {0, 1, 2, 3}
    for key, total in r.traffic.items():
        assert total == sum(t[key] for t in r.per_device.values()), key
    assert r.device_summary().count("device") == 4


def test_emitted_writes_register_in_destination_wtts():
    cfg = FAST.with_(engine=EngineKind.EVENT)
    sc = get_scenario("ring_allreduce")(cfg, closed_loop=True)
    cluster = Cluster(cfg, sc)
    cluster.run()
    steps = sc.steps
    per_flag = 1 + sc.writes_per_step  # flag + marker data writes
    for node in cluster.nodes:
        assert node.wtt.stats.registered == steps * per_flag
        assert node.wtt.stats.enacted == steps * per_flag
        assert node.wtt.empty


def test_sweep_runner_devices_axis():
    from repro.core import SweepRunner

    runner = SweepRunner("ring_allreduce", FAST, engines=(EngineKind.EVENT,))
    points = runner.run(devices=[2, 4], closed_loop=[True])
    assert len(points) == 2
    assert [p.overrides["n_egpus"] for p in points] == [1, 3]
    spans = [p.report.kernel_span_ns for p in points]
    assert spans[1] > spans[0]  # more ring steps -> longer kernel


# ---------------------------------------------------------------------------
# cohort interpreter equivalence (the perf tentpole must not change physics)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", CLOSED_LOOP)
def test_cohort_interpreter_matches_singleton_interpreter(name):
    """The cohort-batched interpreter must be bit-identical to the
    per-workgroup (singleton) interpreter: same traffic, same per-device
    breakdown, same timeline segments."""
    cfg = FAST.with_(engine=EngineKind.EVENT)
    reports = {}
    for cohorts in (True, False):
        sc = get_scenario(name)(cfg, closed_loop=True)
        reports[cohorts] = Cluster(cfg, sc, cohorts=cohorts).run()
    a, b = reports[True], reports[False]
    assert a.traffic == b.traffic
    assert a.per_device == b.per_device
    assert a.kernel_span_ns == pytest.approx(b.kernel_span_ns)
    assert a.sim_cycles == b.sim_cycles
    assert _segments_key(a) == _segments_key(b)


def test_cohorts_group_dispatch_waves():
    """Workgroups sharing (dispatch cycle, phase program) collapse into one
    cohort per wave under SPIN; SyncMon batches by requeue-jitter class, which
    under the default config (jitter mod > wave width, staggered waves) leaves
    every class a singleton — see tests/test_hierarchy.py for configs where
    the classes genuinely batch."""
    cfg = FAST.with_(engine=EngineKind.EVENT)
    sc = get_scenario("ring_allreduce")(cfg, closed_loop=True)
    cluster = Cluster(cfg, sc)
    dev = cluster.nodes[0].target
    assert dev.n_wgs == cfg.workgroups
    assert len(dev.cohorts) == cfg.workgroups // cfg.n_cus  # one per wave
    assert all(c.count == cfg.n_cus for c in dev.cohorts)
    # members of one cohort are consecutive (emission order preservation)
    for c in dev.cohorts:
        assert list(c.members) == list(range(c.members[0], c.members[-1] + 1))

    syncmon = FAST.with_(engine=EngineKind.EVENT, sync=SyncPolicy.SYNCMON)
    sc2 = get_scenario("ring_allreduce")(syncmon, closed_loop=True)
    dev2 = Cluster(syncmon, sc2).nodes[0].target
    assert len(dev2.cohorts) == syncmon.workgroups  # singletons


# ---------------------------------------------------------------------------
# WTT tie-break: seeded traces + emitted writes sharing a wakeup cycle
# ---------------------------------------------------------------------------


def test_seeded_traces_into_closed_loop_cluster_share_wakeup_cycle():
    """Regression: a warm-started closed loop used to crash in heapq.

    WTT heap entries were (cycle, seq, RegisteredWrite) with the unorderable
    RegisteredWrite as the final element; trace-bundle seqs and the cluster's
    emission seqs both start at 0, so a seeded write and an emitted write
    sharing a wakeup cycle compared the frozen dataclasses and raised
    TypeError.  The WTT's own registration counter now breaks ties.
    """
    cfg = FAST.with_(engine=EngineKind.EVENT, include_data_writes=False)

    # discover the first emitted flag's arrival cycle at device 1 (seq 0 in
    # the cluster's emission order: src 0 -> dst 1, ring step 0)
    probe = Cluster(cfg, RingAllReduceScenario(cfg, closed_loop=True))
    probe.run()
    arrivals = probe.nodes[1].target.flag_set_cycle
    first_cycle = min(arrivals.values())

    class SeededRing(RingAllReduceScenario):
        """Closed-loop ring whose device 1 is warm-started with one write
        timed to land exactly on the first emitted flag's wakeup cycle."""

        name = "ring_allreduce"  # same registry key; not re-registered

        def traces_for(self, device):
            bundle = super().traces_for(device)
            if device == 1:
                # Cluster adds xgmi_enact_latency_ns to seeded writes, so
                # subtract it to hit first_cycle exactly; seq stays 0 — the
                # collision with the first emitted write's seq.
                bundle.add(
                    wakeup_ns=self.cfg.cycles_to_ns(first_cycle)
                    - self.cfg.xgmi_enact_latency_ns,
                    addr=self.amap.partial_base,
                    data=0xAB,
                    size=8,
                    src=3,
                )
            return bundle

    sc = SeededRing(cfg, closed_loop=True)
    cluster = Cluster(cfg, sc)
    report = cluster.run()  # pre-fix: TypeError from heapq on registration
    # the seeded write was enacted on top of the normal closed-loop traffic
    assert report.per_device[1]["xgmi_writes_in"] == (
        report.per_device[0]["xgmi_writes_in"] + 1
    )
    assert cluster.nodes[1].wtt.empty

    # pop order at the shared cycle follows registration order: seeds first
    wtt = cluster.nodes[1].wtt
    assert wtt.stats.registered == sc.steps + 1


def test_precomputed_traffic_deltas_mirror_trafficop_apply():
    """The cohort hot path accounts traffic from per-spec precomputed deltas;
    TrafficOp.apply(memory, times=n) is the reference implementation.  Pin
    the two together so they cannot drift."""
    from repro.core.memory import DirectoryMemory

    cfg = FAST.with_(engine=EngineKind.EVENT)
    sc = get_scenario("ring_allreduce")(cfg, closed_loop=True)
    dev = Cluster(cfg, sc).nodes[0].target
    # deltas are computed lazily on first use; drive the memoizing accessor
    specs = {id(spec): spec for c in dev.cohorts for spec in c.phases}
    checked = 0
    for spec in specs.values():
        delta = dev._tdelta_for(spec)
        assert dev._tdelta_for(spec) is delta or delta is None
        if delta is None:
            assert not spec.traffic
            continue
        mem = DirectoryMemory(sc.amap)
        for op in spec.traffic:
            op.apply(mem, times=3)
        t = mem.traffic
        assert (
            t.nonflag_reads, t.read_bytes, t.local_writes,
            t.write_bytes, t.xgmi_writes_out, t.xgmi_bytes_out,
        ) == tuple(3 * d for d in delta)
        checked += 1
    assert checked > 0
