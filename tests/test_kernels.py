"""Per-kernel shape/dtype sweeps vs. ref.py oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(
        rtol=3e-5, atol=3e-5
    )


@pytest.mark.parametrize("M,K,N", [(128, 512, 1), (256, 1024, 1),
                                   (256, 2048, 4), (64, 256, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemv_sweep(M, K, N, dtype):
    a = jax.random.normal(RNG, (M, K), jnp.float32).astype(dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32).astype(dtype)
    y = ops.gemv(a, x, bm=64, bk=256)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(ref.gemv_ref(a, x), np.float32),
        **_tol(dtype),
    )


@pytest.mark.parametrize("n_dev,my_dev", [(4, 0), (4, 1), (4, 3), (8, 5)])
def test_gemv_tiles_values_and_schedule(n_dev, my_dev):
    M, K = 256, 1024
    a = jax.random.normal(RNG, (M, K), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (K, 1), jnp.float32)
    y, prog = ops.gemv_tiles(a, x, n_dev=n_dev, my_dev=my_dev, bm=32, bk=256)
    np.testing.assert_allclose(
        y, ref.gemv_tiles_ref(a, x, n_dev, my_dev), rtol=3e-5, atol=3e-5
    )
    served = list(np.asarray(prog))
    tiles_per_dev = (M // 32) // n_dev
    # remote-first order: successor owners first, self last (paper Fig. 3)
    expect = []
    for step in range(1, n_dev + 1):
        expect += [(my_dev + step) % n_dev] * tiles_per_dev
    assert served == expect
    assert served[-1] == my_dev  # local tiles computed last


@pytest.mark.parametrize("B,H,KV,D,S", [(1, 4, 1, 32, 512), (2, 8, 2, 64, 1024),
                                        (2, 8, 8, 32, 768)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, KV, D, S, dtype):
    q = jax.random.normal(RNG, (B, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, D), jnp.float32).astype(dtype)
    length = S - 7
    o = ops.decode_attention(q, k, v, jnp.int32(length), bs=256)
    o_ref = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), **_tol(dtype)
    )


def test_decode_attention_respects_length_mask():
    B, H, KV, D, S = 1, 2, 1, 16, 256
    q = jax.random.normal(RNG, (B, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, D), jnp.float32)
    o_small = ops.decode_attention(q, k, v, jnp.int32(10), bs=64)
    # garbage beyond the length must not affect the result
    k2 = k.at[:, 10:].set(99.0)
    v2 = v.at[:, 10:].set(-99.0)
    o_small2 = ops.decode_attention(q, k2, v2, jnp.int32(10), bs=64)
    np.testing.assert_allclose(o_small, o_small2, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [(4, 128), (2, 33, 256), (1, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(RNG, shape, jnp.float32).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32) * 0.2
    y = ops.rmsnorm(x, g, br=32)
    np.testing.assert_allclose(
        np.asarray(y, np.float32),
        np.asarray(ref.rmsnorm_ref(x, g), np.float32),
        **_tol(dtype),
    )
