"""Symbolic program IR: expand() oracles, engine bit-identity, loop space.

Three layers of evidence that the compressed :class:`SymbolicProgram` path
is a pure representation change:

* seeded-random **expansion equality** — every scenario keeps its
  pre-refactor flat construction as an oracle (``_flat_phases`` & friends),
  and ``SymbolicProgram.expand()`` must reproduce it element-for-element
  for random device counts / payloads / devices_per_node;
* **engine bit-identity** — symbolic programs must produce the same traffic
  counters through the event interpreter, the timeline engine, and the
  lockstep bulk solver, across every fabric preset;
* **loop-space verification** — ``verify_symbolic`` must agree with the
  materialized per-step verifier at small scale and stay O(segments) at
  pod scale.

When ``hypothesis`` is installed an extra property test widens the random
coverage; the seeded ``random.Random`` tests below always run.
"""

from __future__ import annotations

import random

import pytest

from repro.core.config import EngineKind, SimConfig
from repro.core.scenario import SymbolicProgram, as_symbolic, simulate
from repro.core.scenarios.all_to_all import AllToAllScenario
from repro.core.scenarios.hierarchical_allreduce import (
    HierarchicalAllReduceScenario,
)
from repro.core.scenarios.pipeline_p2p import PipelineP2PScenario
from repro.core.scenarios.ring_allreduce import RingAllReduceScenario

SEED = 0xE1D01A

# counters that must match bit-for-bit across engine implementations
_KEYS = (
    "flag_reads",
    "nonflag_reads",
    "local_writes",
    "xgmi_writes_in",
    "xgmi_writes_out",
    "xgmi_bytes_in",
    "xgmi_bytes_out",
    "read_bytes",
    "write_bytes",
)


def _cfg(n, wgs=8):
    return SimConfig(engine=EngineKind.EVENT, workgroups=wgs).with_devices(n)


def _assert_expansion(symbolic, flat, where):
    assert isinstance(symbolic, SymbolicProgram), where
    expanded = symbolic.expand()
    assert len(expanded) == len(flat), where
    for i, (a, b) in enumerate(zip(expanded, flat)):
        assert a == b, f"{where} phase {i}: {a!r} != {b!r}"
    # random access must agree with expansion (bisect + memo path)
    if flat:
        rng = random.Random(SEED ^ len(flat))
        for i in [0, len(flat) - 1] + rng.sample(
            range(len(flat)), min(8, len(flat))
        ):
            assert symbolic[i] == flat[i], f"{where} [{i}]"


def _dpn_choices(rng, n):
    divisors = [d for d in (2, 4, 8) if n % d == 0 and d < n]
    return rng.choice(divisors) if divisors else None


def test_ring_allreduce_expand_matches_flat():
    rng = random.Random(SEED)
    for _ in range(12):
        n = rng.choice([2, 3, 4, 5, 7, 8, 12, 16, 24, 33])
        sc = RingAllReduceScenario(
            _cfg(n),
            payload_bytes=rng.choice([4096, 1 << 16, 1 << 20]),
            writes_per_step=rng.randint(0, 6),
            closed_loop=True,
            devices_per_node=_dpn_choices(rng, n),
        )
        for rank in range(n):
            for emit in (False, True):
                _assert_expansion(
                    sc._symbolic_phases(rank, emit=emit),
                    sc._flat_phases(rank, emit=emit),
                    f"ring n={n} rank={rank} emit={emit}",
                )


def test_all_to_all_expand_matches_flat():
    rng = random.Random(SEED + 1)
    for _ in range(12):
        n = rng.choice([2, 3, 4, 6, 8, 9, 16, 17, 32])
        sc = AllToAllScenario(
            _cfg(n),
            tokens_per_device=rng.choice([256, 1024, 4096]),
            token_bytes=rng.choice([128, 512]),
            writes_per_peer=rng.randint(0, 8),
            closed_loop=True,
            devices_per_node=_dpn_choices(rng, n),
        )
        for rank in range(n):
            for emit in (False, True):
                _assert_expansion(
                    sc._symbolic_phases(rank, emit=emit),
                    sc._flat_phases(rank, emit=emit),
                    f"a2a n={n} rank={rank} emit={emit}",
                )


def test_hierarchical_expand_matches_flat():
    rng = random.Random(SEED + 2)
    for _ in range(8):
        dpn = rng.choice([2, 4])
        n = dpn * rng.choice([2, 3, 4, 6])
        sc = HierarchicalAllReduceScenario(
            _cfg(n),
            payload_bytes=rng.choice([4096, 1 << 18, 1 << 20]),
            writes_per_step=rng.randint(0, 5),
            devices_per_node=dpn,
        )
        for dev in range(n):
            _assert_expansion(
                sc._symbolic_phases(dev),
                sc._flat_phases(dev),
                f"hier n={n} dpn={dpn} dev={dev}",
            )


def test_pipeline_expand_matches_flat():
    rng = random.Random(SEED + 3)
    for _ in range(8):
        n = rng.choice([2, 3, 4, 6, 8])
        kw = dict(
            n_microbatches=rng.choice([1, 2, 5, 8, 16]),
            activation_bytes=rng.choice([1 << 14, 1 << 19]),
        )
        open_sc = PipelineP2PScenario(_cfg(n), **kw)
        _assert_expansion(
            open_sc._symbolic_open_phases(),
            open_sc._flat_open_phases(),
            f"pipe-open n={n} {kw}",
        )
        closed = PipelineP2PScenario(_cfg(n), closed_loop=True, **kw)
        for dev in range(n):
            _assert_expansion(
                closed._symbolic_closed_phases(dev),
                closed._flat_closed_phases(dev),
                f"pipe-closed n={n} dev={dev} {kw}",
            )


def test_scenarios_stamp_symbolic_programs():
    # the runtime path must actually carry the compressed IR, not a copy of
    # the flat oracle
    n = 8
    for sc in (
        RingAllReduceScenario(_cfg(n), closed_loop=True),
        AllToAllScenario(_cfg(n), closed_loop=True),
        HierarchicalAllReduceScenario(_cfg(n), devices_per_node=2),
        PipelineP2PScenario(_cfg(n), closed_loop=True),
    ):
        progs = sc.programs_for(0)
        assert as_symbolic(progs[0].phases) is not None, type(sc).__name__


def _counters(r):
    out = {k: r.traffic.get(k) for k in _KEYS}
    out["sim_cycles"] = r.sim_cycles
    out["per_device"] = r.per_device
    out["wtt"] = (r.wtt_registered, r.wtt_enacted)
    return out


@pytest.mark.parametrize("name", ["ring_allreduce", "all_to_all"])
@pytest.mark.parametrize(
    "fabric", [None, "ring", "fat_tree", "rail_optimized", "torus2d",
               "two_tier"]
)
def test_engine_bit_identity_on_symbolic_programs(name, fabric):
    kw = dict(devices=8, closed_loop=True, collect_segments=False)
    if fabric is not None:
        kw.update(fabric=fabric, devices_per_node=2)
    cfg = _cfg(8)
    event = simulate(name, cfg, timeline=False, **kw)
    timeline = simulate(name, cfg, timeline=True, **kw)
    assert _counters(event) == _counters(timeline), (name, fabric)
    cycle = simulate(
        name, cfg.with_(engine=EngineKind.CYCLE), timeline=False, **kw
    )
    # cycle vs event agree on traffic volume (scheduling differs by design)
    for k in ("flag_reads", "nonflag_reads", "xgmi_writes_in",
              "xgmi_bytes_in"):
        assert cycle.traffic.get(k) == event.traffic.get(k), (name, fabric, k)


@pytest.mark.parametrize("name", ["ring_allreduce", "all_to_all"])
@pytest.mark.parametrize("n", [2, 3, 4, 16, 17])
def test_lockstep_bit_identity(name, n):
    kw = dict(devices=n, closed_loop=True, collect_segments=False)
    cfg = _cfg(n, wgs=16)
    fast = simulate(name, cfg, lockstep=True, **kw)
    slow = simulate(name, cfg, lockstep=False, **kw)
    assert fast.meta["program_stats"]["lockstep"] is True
    assert slow.meta["program_stats"]["lockstep"] is False
    assert _counters(fast) == _counters(slow), (name, n)
    fint = {k: v for k, v in fast.meta["fabric"].items() if isinstance(v, int)}
    sint = {k: v for k, v in slow.meta["fabric"].items() if isinstance(v, int)}
    assert fint == sint, (name, n)


def test_lockstep_requires_eligible_shape():
    # cross-rank pipelined chains cannot use the bulk solver; the refusal
    # names the blocked group, rank, phase, and flag
    with pytest.raises(ValueError, match="lockstep") as ei:
        simulate(
            "pipeline_p2p", _cfg(8), lockstep=True, devices=8,
            devices_per_node=2, closed_loop=True, collect_segments=False,
        )
    msg = str(ei.value)
    assert "group 'interior'" in msg
    assert "rank 2" in msg
    assert "wait_flags" in msg
    assert "writer 1" in msg
    # ...but fall back to the generic timeline engine when not forced,
    # recording the same blame in the report
    r = simulate(
        "pipeline_p2p", _cfg(8), devices=8, devices_per_node=2,
        closed_loop=True, collect_segments=False,
    )
    assert r.meta["engine_impl"] == "timeline"
    assert r.meta["program_stats"]["lockstep"] is False
    assert "group 'interior'" in r.meta["lockstep_reason"]


def test_lockstep_engages_group_uniform_tiers():
    # leader/worker group splits compile through the tiered solver on the
    # multi-tier presets (this shape used to be a hard refusal)
    r = simulate(
        "hierarchical_allreduce", _cfg(8), lockstep=True, devices=8,
        devices_per_node=2, closed_loop=True, collect_segments=False,
    )
    assert r.meta["program_stats"]["lockstep"] is True
    assert r.meta["lockstep_reason"] == "engaged"


def test_lockstep_rejects_open_loop():
    with pytest.raises(ValueError, match="closed-loop"):
        simulate("ring_allreduce", _cfg(4), lockstep=True)


def test_program_stats_reported():
    r = simulate(
        "ring_allreduce", _cfg(8), devices=8, closed_loop=True,
        collect_segments=False,
    )
    ps = r.meta["program_stats"]
    assert ps["symbolic_programs"] > 0
    assert ps["flat_programs"] == 0
    assert ps["program_phases"] > ps["segments"]
    assert ps["materialized_phases"] <= ps["program_phases"]
    assert ps["construct_wall_s"] >= 0.0


def test_lockstep_never_materializes():
    r = simulate(
        "ring_allreduce", _cfg(64), devices=64, closed_loop=True,
        collect_segments=False, lockstep=True,
    )
    assert r.meta["program_stats"]["materialized_phases"] == 0


def test_verify_symbolic_agrees_with_materialized():
    from repro.analysis.verify import verify_scenario, verify_symbolic

    for name in ("ring_allreduce", "all_to_all"):
        vs = verify_symbolic(name, devices=8, closed_loop=True)
        vm = verify_scenario(name, devices=8, closed_loop=True)
        assert not [f for f in vs.findings if f.kind == "symbolic-shape"]
        assert vs.ok == vm.ok, name


def test_verify_symbolic_pod_scale_is_loop_space():
    from repro.analysis.verify import verify_symbolic

    # materializing 4096 devices would need O(devices^2) ~ 16M step nodes;
    # loop space stays O(segments x devices) and finishes fast
    for name in ("ring_allreduce", "all_to_all"):
        v = verify_symbolic(name, devices=4096, closed_loop=True)
        assert v.ok, (name, v.findings)


def test_verify_symbolic_shape_skip_is_declared():
    from repro.analysis.verify import verify_symbolic

    # leader/worker groups verify through the tiered group-level lowering:
    # no skip finding any more
    v = verify_symbolic(
        "hierarchical_allreduce", devices=8, devices_per_node=2,
        closed_loop=True,
    )
    assert v.ok
    assert not [f for f in v.findings if f.kind == "symbolic-shape"]

    # cross-rank pipelined chains stay out of both lowerings; the skip
    # carries the tiered compiler's blame
    vp = verify_symbolic(
        "pipeline_p2p", devices=8, devices_per_node=2, closed_loop=True,
    )
    assert vp.ok
    skips = [f for f in vp.findings if f.kind == "symbolic-shape"]
    assert skips
    assert "group 'interior'" in skips[0].message


def test_verify_symbolic_catches_unmatched_wait():
    from repro.analysis.verify import verify_symbolic
    from repro.core.scenario import Affine, LoopPhase, LoopSpec

    class BrokenRing(RingAllReduceScenario):
        def _symbolic_phases(self, rank, *, emit):
            n = self.cfg.n_devices
            # wait on the *downstream* rank's flag column: a well-formed
            # affine family that no emission ever writes into this rank's
            # memory (the upstream neighbor writes its own column)
            bogus = LoopSpec(
                self.steps,
                (
                    LoopPhase(
                        "wait-missing",
                        wait_addrs=(
                            Affine(
                                self.amap.flag_addr((rank + 1) % n, 0),
                                self.amap.flag_stride * n,
                            ),
                        ),
                    ),
                ),
            )
            base = super()._symbolic_phases(rank, emit=emit)
            return SymbolicProgram(tuple(base.segments) + (bogus,))

    sc = BrokenRing(_cfg(4), closed_loop=True)
    v = verify_symbolic(sc)
    assert not v.ok
    assert any(f.kind == "unmatched-wait" for f in v.findings)


# -- hypothesis widening (optional dependency) ------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised only without hypothesis
    pass
else:

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=24),
        payload=st.integers(min_value=1, max_value=1 << 21),
        writes=st.integers(min_value=0, max_value=8),
    )
    def test_hypothesis_ring_expand_matches_flat(n, payload, writes):
        sc = RingAllReduceScenario(
            _cfg(n), payload_bytes=payload, writes_per_step=writes,
            closed_loop=True,
        )
        for rank in (0, n // 2, n - 1):
            _assert_expansion(
                sc._symbolic_phases(rank, emit=True),
                sc._flat_phases(rank, emit=True),
                f"hyp ring n={n} rank={rank}",
            )
