"""Timeline engine (pod-scale closed loop) tests.

Covers the four contracts ``repro.core.cohort_timeline`` makes:

* **bit-identity** — counters, sim_cycles, and segments match the event
  engine exactly, across scenarios, shapes, and fabrics (incl. sanitized
  runs and emit coalescing modes);
* **lazy write runs** — a :class:`LazyWriteRun` descriptor synthesizes, pops,
  and interleaves exactly like the ``count`` materialized registrations it
  stands for, including same-cycle heap tie-breaks and mid-run registration
  (property-tested: seeded-random always, hypothesis when installed);
* **eligibility** — ``timeline=True`` errors loudly when the lockstep-lane
  invariant does not hold (and auto mode falls back silently), and deadlock
  diagnostics are engine-independent;
* **lane replay** — the dense closed form (numpy reference and
  ``jax.lax.scan`` variant) reproduces a real cluster run's flag reads and
  kernel end cycle.
"""

import random
import re

import numpy as np
import pytest

from repro.core import (
    Cluster,
    EidolaDeadlock,
    EmitOp,
    EngineKind,
    PhaseSpec,
    Scenario,
    SimConfig,
    SyncPolicy,
    TraceBundle,
    TrafficOp,
    WGProgram,
    simulate,
)
from repro.core.cohort_timeline import (
    lane_step_arrays,
    replay_lane_numpy,
    timeline_support,
)
from repro.core.events import RegisteredWrite, register_phase
from repro.core.scenarios.ring_allreduce import RingAllReduceScenario
from repro.core.wtt import LazyWriteRun, WriteTrackingTable

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property test falls back to the seeded-random sweep
    HAVE_HYPOTHESIS = False

FAST = SimConfig(workgroups=12, n_cus=4)

CLOSED_LOOP = (
    "ring_allreduce",
    "all_to_all",
    "pipeline_p2p",
    "hierarchical_allreduce",
)

COUNTERS = (
    "flag_reads",
    "nonflag_reads",
    "local_writes",
    "xgmi_writes_in",
    "xgmi_writes_out",
    "xgmi_bytes_in",
    "xgmi_bytes_out",
    "read_bytes",
    "write_bytes",
)


def _segments_key(report):
    return sorted(
        (s.device, s.wg, s.phase, round(s.start_ns, 6), round(s.end_ns, 6))
        for s in report.segments
    )


def _run_pair(name, **kw):
    a = simulate(name, FAST, closed_loop=True, timeline=False, **kw)
    b = simulate(name, FAST, closed_loop=True, timeline=True, **kw)
    assert a.meta["engine_impl"] == "event"
    assert b.meta["engine_impl"] == "timeline"
    assert b.engine == "event"  # same semantics: bench row keys comparable
    return a, b


def _assert_reports_equal(a, b):
    for k in COUNTERS:
        assert a.traffic.get(k) == b.traffic.get(k), k
    assert a.sim_cycles == b.sim_cycles
    assert a.kernel_span_ns == b.kernel_span_ns
    assert a.wtt_enacted == b.wtt_enacted
    assert _segments_key(a) == _segments_key(b)


# ---------------------------------------------------------------------------
# bit-identity against the event engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", CLOSED_LOOP)
def test_timeline_bit_identical_flat(name):
    a, b = _run_pair(name, devices=4, sanitize=True)
    _assert_reports_equal(a, b)


@pytest.mark.parametrize("name", CLOSED_LOOP)
@pytest.mark.parametrize("fabric", ["fat_tree", "rail_optimized"])
def test_timeline_bit_identical_tiered(name, fabric):
    a, b = _run_pair(
        name, devices=8, devices_per_node=4, fabric=fabric, sanitize=True
    )
    _assert_reports_equal(a, b)


def test_timeline_breakdown_reported():
    r = simulate(
        "ring_allreduce", FAST, devices=4, closed_loop=True, timeline=True,
        lockstep=False, collect_segments=False,
    )
    bd = r.meta["wall_breakdown"]
    assert set(bd) == {"interpreter_s", "fabric_s", "wtt_s", "other_s"}
    assert all(isinstance(v, float) and v >= 0.0 for v in bd.values())
    assert sum(bd.values()) <= r.wall_time_s + 1e-6


def test_lockstep_breakdown_reported():
    r = simulate(
        "ring_allreduce", FAST, devices=4, closed_loop=True, lockstep=True,
        collect_segments=False,
    )
    assert r.meta["program_stats"]["lockstep"] is True
    bd = r.meta["wall_breakdown"]
    assert set(bd) == {"compile_s", "solve_s", "writeback_s"}
    assert all(isinstance(v, float) and v >= 0.0 for v in bd.values())
    assert sum(bd.values()) <= r.wall_time_s + 1e-6


class _ProgramScenario(Scenario):
    """Closed-loop scenario whose per-rank phases come from a callback."""

    name = "_timeline_program_scenario"
    closed_loop = True

    def __init__(self, cfg, phases_fn, amap=None):
        super().__init__(cfg, amap)
        self._phases_fn = phases_fn

    def programs_for(self, device):
        shared = tuple(self._phases_fn(self, device))
        return [
            WGProgram(wg=w, cu=w % self.cfg.n_cus, dispatch_cycle=0,
                      phases=shared)
            for w in range(self.cfg.workgroups)
        ]

    def programs(self):
        return self.programs_for(0)

    def traces(self):
        return TraceBundle()


for _name in ("tl_burst", "tl_settle", "tl_wait", "tl_drain", "tl_stuck",
              "tl_busy"):
    register_phase(_name)


def _mixed_emit_phases(sc, device):
    """Rank 0 emits both per-workgroup ('each') and coalesced ('last')
    bursts with marker data writes (the LazyWriteRun path); rank 1 waits."""
    if device == 0:
        return [
            PhaseSpec(
                "tl_burst", 40,
                traffic=(TrafficOp("reads", 2, 64),),
                emits=(
                    EmitOp(dst=1, slot=0, payload_bytes=4096,
                           data_writes=5, coalesce="each"),
                ),
            ),
            PhaseSpec(
                "tl_settle", 60,
                traffic=(TrafficOp("local_writes", 1, 64),),
                emits=(
                    EmitOp(dst=1, slot=1, payload_bytes=256,
                           data_writes=3, coalesce="last"),
                ),
            ),
        ]
    return [
        PhaseSpec("tl_wait", wait_addrs=(sc.amap.flag_addr(0, slot=0),)),
        PhaseSpec("tl_wait", wait_addrs=(sc.amap.flag_addr(0, slot=1),)),
        PhaseSpec("tl_drain", 25, traffic=(TrafficOp("reads", 3, 64),)),
    ]


def test_timeline_bit_identical_mixed_emits():
    from repro.core import AddressMap

    cfg = FAST.with_(n_egpus=1)  # 2 devices
    reports = {}
    for tl in (False, True):
        sc = _ProgramScenario(
            cfg, _mixed_emit_phases,
            amap=AddressMap(n_devices=2, flag_slots=2),
        )
        r = Cluster(cfg, sc, timeline=tl, sanitize=True).run()
        assert r.meta["engine_impl"] == ("timeline" if tl else "event")
        reports[tl] = r
    _assert_reports_equal(reports[False], reports[True])


# ---------------------------------------------------------------------------
# lazy write runs: descriptor == materialized registrations
# ---------------------------------------------------------------------------


def _eager_writes(run):
    """The count materialized writes a LazyWriteRun stands for, built with
    the eager path's exact float expression (cycle rounding must agree)."""
    out = []
    for k in range(run.count):
        t = run.base_ns + run.span_ns * (k + 1) / (run.count + 1)
        if t < run.min_ns:
            t = run.min_ns
        out.append(
            RegisteredWrite(
                wakeup_ns=t,
                addr=run.addr_base + k * run.addr_stride,
                data=run.data,
                size=run.size,
                src=run.src,
                seq=run.seq0 + k,
            )
        )
    return out


def _drain(wtt):
    """Pop every (cycle, write-key) pair in enactment order."""
    out = []
    while True:
        cyc, group = wtt.pop_next_group()
        if cyc is None:
            return out
        for w in group:
            out.append((cyc, w.addr, w.data, w.size, w.src, w.seq))


def _check_run_equivalence(run, extra_writes=(), pops_before_extra=0):
    """Lazy table (descriptor) and eager table (materialized writes) see the
    same registration/pop sequence; their pop streams must be identical."""
    lazy = WriteTrackingTable()
    eager = WriteTrackingTable()
    lazy.register_many([run])
    eager.register_many(_eager_writes(run))
    assert len(lazy) == len(eager) == run.count
    got, want = [], []
    for _ in range(pops_before_extra):
        ca, ga = lazy.pop_next_group()
        cb, gb = eager.pop_next_group()
        got.append((ca, [(w.addr, w.seq) for w in ga]))
        want.append((cb, [(w.addr, w.seq) for w in gb]))
    if extra_writes:
        lazy.register_many(list(extra_writes))
        eager.register_many(list(extra_writes))
    got.extend(_drain(lazy))
    want.extend(_drain(eager))
    assert got == want
    assert len(lazy) == len(eager) == 0


def test_lazy_run_matches_eager_seeded_random():
    rng = random.Random(0xE1D01A)
    for _ in range(120):
        count = rng.randint(1, 40)
        base = rng.choice([0.0, rng.uniform(0, 5000)])
        span = rng.choice([0.0, rng.uniform(0, 3000)])
        run = LazyWriteRun(
            count=count,
            base_ns=base,
            span_ns=span,
            addr_base=0x1000,
            addr_stride=rng.choice([0, 8, 64]),
            data=rng.randint(0, 2**31),
            size=rng.choice([4, 8]),
            src=rng.randint(0, 7),
            seq0=rng.randint(0, 100),
            min_ns=rng.choice([0.0, base + span * rng.uniform(0, 1.2)]),
        )
        # a mid-run registration landing inside the run's cycle range (often
        # exactly on a member's cycle: the reg_no tie-break must agree too)
        member_ns = run.wakeup_ns(rng.randrange(count))
        extra = [
            RegisteredWrite(
                wakeup_ns=member_ns, addr=0x9000, data=1, size=8, src=9
            ),
            RegisteredWrite(
                wakeup_ns=member_ns + rng.uniform(0, 100),
                addr=0x9040, data=2, size=8, src=9,
            ),
        ]
        _check_run_equivalence(
            run, extra_writes=extra,
            pops_before_extra=rng.randint(0, min(3, count)),
        )


def test_lazy_run_same_cycle_tie_breaks():
    # span 0: every member lands on the same cycle; pop order must be the
    # registration order (contiguous reg_no block), before later same-cycle
    # registrations from other producers
    run = LazyWriteRun(count=8, base_ns=100.0, span_ns=0.0,
                       addr_base=0x2000, addr_stride=8, data=7, size=8)
    tied = RegisteredWrite(wakeup_ns=100.0, addr=0x8000, data=3, size=8)
    _check_run_equivalence(run, extra_writes=[tied])


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(
        count=st.integers(min_value=1, max_value=64),
        base=st.floats(0, 1e5, allow_nan=False, allow_infinity=False),
        span=st.floats(0, 1e4, allow_nan=False, allow_infinity=False),
        stride=st.sampled_from([0, 8, 64]),
        min_frac=st.floats(0, 1.5),
        pops=st.integers(min_value=0, max_value=3),
    )
    def test_lazy_run_matches_eager_hypothesis(
        count, base, span, stride, min_frac, pops
    ):
        run = LazyWriteRun(
            count=count, base_ns=base, span_ns=span,
            addr_base=0x1000, addr_stride=stride, data=11, size=8,
            min_ns=(base + span) * min_frac,
        )
        extra = [
            RegisteredWrite(wakeup_ns=run.wakeup_ns(count // 2),
                            addr=0x9000, data=1, size=8)
        ]
        _check_run_equivalence(
            run, extra_writes=extra, pops_before_extra=min(pops, count)
        )

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_lazy_run_matches_eager_hypothesis():
        pass


def test_pop_due_run_is_prefix_of_pop_next_group():
    def build():
        w = WriteTrackingTable()
        w.register_many([
            LazyWriteRun(count=10, base_ns=100.0, span_ns=900.0,
                         addr_base=0x1000, addr_stride=8, data=5, size=8),
            RegisteredWrite(wakeup_ns=550.0, addr=0x9000, data=1, size=8),
        ])
        return w

    ref = build()
    want = _drain(ref)

    wtt = build()
    got = []
    res = wtt.pop_due_run(None)
    assert res is not None
    cycles, addrs, data, size = res
    assert len(cycles) == len(addrs)
    # the bulk pop must stop before the interleaved plain write's key
    assert all(c <= wtt.ns_to_cycles(550.0) for c in cycles)
    got.extend((c, a, data, size, 5, i)
               for i, (c, a) in enumerate(zip(cycles, addrs)))
    # fix up src/seq fields for comparison: members carry src=-1 here
    got = [(c, a, d, s) for c, a, d, s, _, _ in got]
    rest = [(c, a, d, s) for c, a, d, s, _, _ in _drain(wtt)]
    assert got + rest == [(c, a, d, s) for c, a, d, s, _, _ in want]
    assert len(wtt) == 0


def test_pop_due_run_respects_stop_cycle():
    wtt = WriteTrackingTable()
    run = LazyWriteRun(count=10, base_ns=100.0, span_ns=900.0,
                       addr_base=0x1000, addr_stride=8, data=5, size=8)
    wtt.register_many([run])
    stop = wtt.ns_to_cycles(run.wakeup_ns(4))
    cycles, addrs, _, _ = wtt.pop_due_run(stop)
    assert all(c <= stop for c in cycles)
    assert len(wtt) == run.count - len(cycles)
    # the remainder still pops in order
    rest = _drain(wtt)
    assert len(rest) == run.count - len(cycles)
    assert [a for _, a, *_ in rest] == [
        0x1000 + 8 * k for k in range(len(cycles), run.count)
    ]


def test_pop_due_run_returns_none_on_plain_head():
    wtt = WriteTrackingTable()
    wtt.register(RegisteredWrite(wakeup_ns=10.0, addr=0x10, data=1, size=8))
    assert wtt.pop_due_run(None) is None
    assert len(wtt) == 1  # untouched


# ---------------------------------------------------------------------------
# eligibility and fallback
# ---------------------------------------------------------------------------


def test_timeline_true_rejects_cohorts_off():
    sc = RingAllReduceScenario(FAST)
    sc.closed_loop = True
    with pytest.raises(ValueError, match="cohorts=False"):
        Cluster(FAST, sc, cohorts=False, timeline=True).run()


def test_timeline_true_rejects_cycle_engine():
    cfg = FAST.with_(engine=EngineKind.CYCLE)
    sc = RingAllReduceScenario(cfg)
    sc.closed_loop = True
    with pytest.raises(ValueError, match="EngineKind.EVENT"):
        Cluster(cfg, sc, timeline=True).run()


def test_timeline_true_rejects_syncmon():
    cfg = FAST.with_(sync=SyncPolicy.SYNCMON)
    sc = RingAllReduceScenario(cfg)
    sc.closed_loop = True
    with pytest.raises(ValueError, match="SPIN"):
        Cluster(cfg, sc, timeline=True).run()


class _SlowReduce:
    def scale_phase(self, wg, name, cycles):
        return cycles * 3 if name == "ring_reduce" else cycles

    def jitter_write(self, w):
        return w


def test_timeline_auto_falls_back_on_perturbation():
    r = simulate(
        "ring_allreduce", FAST, devices=4, closed_loop=True,
        perturb={1: _SlowReduce()},
    )
    assert r.meta["engine_impl"] == "event"
    with pytest.raises(ValueError, match="perturbation"):
        simulate(
            "ring_allreduce", FAST, devices=4, closed_loop=True,
            perturb={1: _SlowReduce()}, timeline=True,
        )


def test_timeline_opt_out_is_respected_and_named():
    class _OptOut(RingAllReduceScenario):
        timeline_opt_out = "exercises per-member wake interleaving"

    sc = _OptOut(FAST)
    sc.closed_loop = True
    cl = Cluster(FAST, sc)
    assert "exercises per-member wake interleaving" in timeline_support(cl)
    r = cl.run()
    assert r.meta["engine_impl"] == "event"
    sc2 = _OptOut(FAST)
    sc2.closed_loop = True
    with pytest.raises(ValueError, match="opts out"):
        Cluster(FAST, sc2, timeline=True).run()


def test_timeline_requires_closed_loop():
    with pytest.raises(ValueError, match="closed-loop"):
        simulate("gemv_allreduce", FAST, timeline=True)


def test_timeline_deadlock_parity():
    def phases(sc, device):
        if device == 0:
            # waits on a flag no peer ever emits
            return [PhaseSpec("tl_stuck",
                              wait_addrs=(sc.amap.flag_addr(1, slot=0),))]
        return [PhaseSpec("tl_busy", 50, traffic=(TrafficOp("reads", 1, 64),))]

    cfg = FAST.with_(n_egpus=1)  # 2 devices
    msgs = {}
    for tl in (False, True):
        sc = _ProgramScenario(cfg, phases)
        with pytest.raises(EidolaDeadlock) as ei:
            Cluster(cfg, sc, timeline=tl).run()
        # the detection cycle is engine bookkeeping (when the queue was
        # noticed empty), not part of the diagnosis — normalize it
        msgs[tl] = re.sub(r"at cycle \d+", "at cycle N", str(ei.value))
    assert msgs[False] == msgs[True]
    assert "device 0" in msgs[True]
    assert "wg 0-11" in msgs[True]


# ---------------------------------------------------------------------------
# dense lane replay (numpy reference, jax variant)
# ---------------------------------------------------------------------------


def _lane_inputs(cluster):
    """Per-device (dispatch vector, member counts, step arrays) after a run."""
    out = {}
    for node in cluster.nodes:
        tgt = node.target
        dispatch = np.array(
            [c.program.dispatch_cycle for c in tgt.cohorts], np.int64
        )
        counts = np.array([c.count for c in tgt.cohorts], np.int64)
        is_wait, val = lane_step_arrays(
            tgt.cohorts[0].phases, tgt.flag_set_cycle
        )
        out[node.device_id] = (dispatch, counts, is_wait, val)
    return out


def test_replay_numpy_matches_real_run():
    cfg = FAST
    sc = RingAllReduceScenario(cfg)
    sc.closed_loop = True
    cl = Cluster(cfg, sc, timeline=True)
    cl.run()
    for dev, (dispatch, counts, is_wait, val) in _lane_inputs(cl).items():
        reads, end = replay_lane_numpy(
            dispatch, is_wait, val,
            poll=cfg.poll_interval_cycles, check=cfg.flag_check_cycles,
        )
        node = cl.nodes[dev]
        assert int((reads * counts).sum()) == node.memory.traffic.flag_reads
        assert int(end.max()) == node.target.kernel_end_cycle


def test_replay_jax_matches_numpy():
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.core.cohort_timeline import replay_lane_jax

    rng = np.random.default_rng(7)
    for _ in range(10):
        n_steps = rng.integers(1, 30)
        n_cohorts = rng.integers(1, 12)
        is_wait = rng.random(n_steps) < 0.5
        val = np.where(
            is_wait,
            rng.integers(0, 5000, n_steps),
            rng.integers(1, 400, n_steps),
        ).astype(np.int64)
        dispatch = rng.integers(0, 300, n_cohorts).astype(np.int64)
        r_np, t_np = replay_lane_numpy(dispatch, is_wait, val, poll=64,
                                       check=4)
        r_jx, t_jx = replay_lane_jax(dispatch, is_wait, val, poll=64, check=4)
        np.testing.assert_array_equal(r_np, np.asarray(r_jx, np.int64))
        np.testing.assert_array_equal(t_np, np.asarray(t_jx, np.int64))
