"""Tiered lockstep: group-uniform bulk solving over multi-tier fabrics.

Seeded-random cross-engine identity and group-IR round-trips that must run
unconditionally (the hypothesis-driven shape sweep lives in
``test_property.py`` and is skipped when hypothesis is absent).
"""

import random

from repro.core import EngineKind, SimConfig
from repro.core.scenario import get_scenario, simulate

_KEYS = (
    "flag_reads", "nonflag_reads", "local_writes", "xgmi_writes_in",
    "xgmi_writes_out", "xgmi_bytes_in", "xgmi_bytes_out", "read_bytes",
    "write_bytes",
)


def _sig(r):
    return (
        tuple(r.traffic.get(k) for k in _KEYS),
        r.sim_cycles,
        tuple(sorted(
            (d, tuple(sorted(t.items()))) for d, t in r.per_device.items()
        )),
        (r.wtt_registered, r.wtt_enacted),
        tuple(sorted(
            (k, v) for k, v in r.meta["fabric"].items()
            if isinstance(v, int)
        )),
    )


def test_three_engine_bit_identity_seeded():
    # seeded random shapes through all three implementations: the per-WG
    # event interpreter, the cohort timeline, and the tiered bulk solver
    rng = random.Random(0x51D07A)
    names = ["ring_allreduce", "all_to_all", "hierarchical_allreduce"]
    fabrics = ["two_tier", "fat_tree", "rail_optimized"]
    for _ in range(3):
        name = rng.choice(names)
        fabric = rng.choice(fabrics)
        dpn = rng.choice([2, 3, 4])
        n = dpn * rng.randint(2, 5)
        cfg = SimConfig(engine=EngineKind.EVENT, workgroups=4).with_devices(n)
        kw = dict(devices=n, closed_loop=True, collect_segments=False,
                  devices_per_node=dpn, fabric=fabric)
        event = simulate(name, cfg, timeline=False, **kw)
        timeline = simulate(name, cfg, lockstep=False, **kw)
        lockstep = simulate(name, cfg, lockstep=True, **kw)
        assert timeline.meta["engine_impl"] == "timeline"
        assert lockstep.meta["lockstep_reason"] == "engaged"
        s_event = _sig(event)
        assert s_event == _sig(timeline), (name, fabric, n, dpn)
        assert s_event == _sig(lockstep), (name, fabric, n, dpn)


def test_tiered_identity_all_scenarios_all_fabrics():
    # every closed-loop scenario x every tiered preset at one odd shape;
    # pipeline falls back (identity then holds trivially, but the recorded
    # reason must carry the group blame)
    for name in ("ring_allreduce", "all_to_all", "hierarchical_allreduce",
                 "pipeline_p2p"):
        for fabric in ("two_tier", "fat_tree", "rail_optimized"):
            n, dpn = 12, 4
            cfg = SimConfig(
                engine=EngineKind.EVENT, workgroups=4,
            ).with_devices(n)
            kw = dict(devices=n, closed_loop=True, collect_segments=False,
                      devices_per_node=dpn, fabric=fabric)
            fast = simulate(name, cfg, **kw)  # lockstep auto-selects
            slow = simulate(name, cfg, lockstep=False, **kw)
            if name == "pipeline_p2p":
                assert "group" in fast.meta["lockstep_reason"]
                assert fast.meta["program_stats"]["lockstep"] is False
            else:
                assert fast.meta["lockstep_reason"] == "engaged", (
                    name, fabric, fast.meta["lockstep_reason"],
                )
            assert _sig(fast) == _sig(slow), (name, fabric)


def test_ring_flag_pool_clears_partial_region():
    # per-step flag slots would overrun the default flag/partial gap beyond
    # ~256 devices; the scenario's map must keep the regions disjoint so
    # data-marker writes can never alias (and stale-satisfy) ring-step flags
    ring = get_scenario("ring_allreduce")
    for n in (8, 256, 512, 4096):
        amap = ring.default_amap(SimConfig().with_devices(n))
        assert amap.flag_region()[1] <= amap.partial_base, n
    small = ring.default_amap(SimConfig().with_devices(8))
    from repro.core.memory import AddressMap

    assert small.partial_base == AddressMap.partial_base  # no-op below scale


def test_marker_alias_declines_with_blame():
    # hierarchical_allreduce's *legacy* layout (no partial clearance) lets
    # data-marker writes reach high flag slots at 256 nodes; the solver must
    # refuse (the engines resolve waits by value, so a stale marker satisfies
    # them early) and name the rank and flag.  The shipped default_amap
    # re-bases partial_base above the pool, so the legacy map is rebuilt here
    # explicitly: 512 devices, dpn=2 -> bcast_slot 512, a 16.8 MB pool that
    # overruns the default 16.7 MB flag/partial gap
    import pytest

    from repro.core.memory import AddressMap

    legacy = AddressMap(n_devices=512, flag_slots=513)
    assert legacy.flag_region()[1] > legacy.partial_base  # still aliases
    cfg = SimConfig(engine=EngineKind.EVENT, workgroups=4).with_devices(512)
    with pytest.raises(ValueError, match=r"data-marker writes on rank \d+"
                                         r" reach flag \(writer \d+, slot"):
        simulate(
            "hierarchical_allreduce", cfg, devices=512, closed_loop=True,
            collect_segments=False, devices_per_node=2, fabric="two_tier",
            lockstep=True, amap=legacy,
        )


def test_hierarchical_pod_lockstep_engages():
    # the clearance re-base is the whole point: the same 512-device shape
    # that declines under the legacy map now engages the tiered solver and
    # stays bit-identical to the cohort timeline
    cfg = SimConfig(engine=EngineKind.EVENT, workgroups=4).with_devices(512)
    kw = dict(devices=512, closed_loop=True, collect_segments=False,
              devices_per_node=2, fabric="two_tier")
    fast = simulate("hierarchical_allreduce", cfg, lockstep=True, **kw)
    slow = simulate("hierarchical_allreduce", cfg, lockstep=False, **kw)
    assert fast.meta["lockstep_reason"] == "engaged"
    assert _sig(fast) == _sig(slow)


def test_group_classification_roundtrips_expand():
    # the tiered plan's per-group schedule must replay each member rank's
    # SymbolicProgram.expand() phase-for-phase (names and order)
    from repro.core.cluster import Cluster
    from repro.core.lockstep_tiered import compile_tiered
    from repro.core.scenario import as_symbolic

    for name, n, dpn in (
        ("ring_allreduce", 12, 4),
        ("all_to_all", 12, 4),
        ("hierarchical_allreduce", 12, 4),
        ("hierarchical_allreduce", 33, 3),
    ):
        cfg = SimConfig(engine=EngineKind.EVENT, workgroups=4).with_devices(n)
        sc = get_scenario(name)(
            cfg, closed_loop=True, devices_per_node=dpn, fabric="two_tier",
        )
        plan = compile_tiered(Cluster(cfg, sc, collect_segments=False))
        seen = set()
        for grp in plan.groups:
            sched = [
                ph.name
                for seg in grp.segs
                for _ in range(seg.count)
                for ph in seg.body
            ]
            for dev in grp.devs:
                dev = int(dev)
                seen.add(dev)
                sp = as_symbolic(sc.programs_for(dev)[0].phases)
                assert sp is not None
                expanded = [p.name for p in sp.expand()]
                assert sched == expanded, (name, n, dpn, dev)
        assert seen == set(range(n)), (name, n, dpn)
