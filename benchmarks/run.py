"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus a pass/fail comparison
against the paper's claims, and saves the full results to
``results/benchmarks.json``.

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="skip slow sweeps")
    ap.add_argument("--out", default="results/benchmarks.json")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import kernels_bench, paper_figs

    results = {}
    csv_rows = ["name,us_per_call,derived"]

    def record(name, fn):
        t0 = time.perf_counter()
        out = fn()
        dt = (time.perf_counter() - t0) * 1e6
        results[name] = out
        passes = {k: v for k, v in out.items() if k.startswith("pass")}
        derived = ";".join(f"{k}={v}" for k, v in passes.items())
        csv_rows.append(f"{name},{dt:.0f},{derived}")
        status = "PASS" if all(passes.values()) else "FAIL"
        print(f"[bench] {name:24s} {status}  {derived}")
        if "paper_claim" in out:
            print(f"        paper: {out['paper_claim']}")
        return out

    print("=" * 72)
    print("Eidola paper-figure reproductions")
    print("=" * 72)
    f6 = record("fig6_wakeup_sweep", paper_figs.fig6_wakeup_sweep)
    print(f"        slope={f6['slope_per_us']:.0f} reads/us r2={f6['r2']:.6f} "
          f"nonflag={f6['nonflag_reads']:,}")
    f9 = record("fig9_syncmon", paper_figs.fig9_syncmon)
    print(f"        band=[{f9['min_reads']}, {f9['max_reads']}] "
          f"(paper: [728, 788]) nonflag={f9['nonflag_reads']:,}")
    if not args.quick:
        f10 = record("fig10_scaling_m", paper_figs.fig10_scaling_m)
        print(f"        r2={f10['r2']:.3f} over M=256..4096")
        f11 = record("fig11_scaling_egpus", paper_figs.fig11_scaling_egpus)
        print(
            f"        normalized t(255 eGPUs)={f11['normalized_at_max']:.1f}x "
            f"(paper: 7.3x-35.9x; linear would be 256x)"
        )
        f11m = record(
            "fig11_scaling_egpus_mwait",
            lambda: paper_figs.fig11_scaling_egpus(syncmon=True),
        )
        print(f"        mwait-instrumented: {f11m['normalized_at_max']:.1f}x")
    f12 = record("fig12_variability", paper_figs.fig12_variability)
    print(f"        wait inflation {f12['wait_inflation']:.1f}x; "
          f"kernel {f12['ideal_kernel_ns']:.0f} -> "
          f"{f12['contended_kernel_ns']:.0f} ns")
    print(f12["ascii_contended"])
    eng = record("engine_comparison", paper_figs.engine_comparison)
    print(
        f"        event {eng['speedup_event_vs_cycle']:.1f}x / vector "
        f"{eng['speedup_vector_vs_cycle']:.1f}x vs per-cycle polling"
    )

    print("-" * 72)
    print("Pallas kernel micro-benchmarks (interpret mode)")
    for name, out in kernels_bench.all_benches().items():
        results[f"kernel_{name}"] = out
        print(f"[bench] kernel_{name:17s} "
              f"{'PASS' if out['pass'] else 'FAIL'} rows={len(out['rows'])}")
        csv_rows.append(f"kernel_{name},0,pass={out['pass']}")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print("-" * 72)
    print("\n".join(csv_rows))
    failures = [
        n for n, out in results.items()
        if not all(v for k, v in out.items() if k.startswith("pass"))
    ]
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print(f"all benchmarks pass; results -> {args.out}")


if __name__ == "__main__":
    main()
