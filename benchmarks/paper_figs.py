"""Reproductions of the paper's experiments (Figures 6, 9, 10, 11, 1/2).

Each function mirrors one figure/table and returns a dict of results plus a
pass/fail comparison against the paper's claims.  ``benchmarks.run`` drives
all of them and prints the CSV summary.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (
    EngineKind,
    GaussianPerturb,
    PeerDelayPerturb,
    SimConfig,
    SyncPolicy,
    run_gemv_allreduce,
)
from repro.core.trace_render import ascii_timeline, phase_totals

SWEEP_US = list(range(0, 41, 5))  # the paper's 0..40 us wakeupTime sweep


def _linfit_r2(xs, ys):
    fit = np.polyfit(xs, ys, 1)
    pred = np.polyval(fit, xs)
    ss_res = float(((np.array(ys) - pred) ** 2).sum())
    ss_tot = float(((np.array(ys) - np.mean(ys)) ** 2).sum())
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    return float(fit[0]), float(fit[1]), r2


# ---------------------------------------------------------------------------
# Figure 6: spin-wait flag reads grow linearly with wakeupTime
# ---------------------------------------------------------------------------


def fig6_wakeup_sweep(engine=EngineKind.EVENT) -> Dict:
    rows = []
    for d_us in SWEEP_US:
        cfg = SimConfig(sync=SyncPolicy.SPIN, engine=engine)
        r = run_gemv_allreduce(cfg, d_us * 1000.0, collect_segments=False)
        rows.append(
            {"wakeup_us": d_us, "flag_reads": r.flag_reads,
             "nonflag_reads": r.nonflag_reads}
        )
    slope, icpt, r2 = _linfit_r2(
        [r["wakeup_us"] for r in rows], [r["flag_reads"] for r in rows]
    )
    nonflag = rows[0]["nonflag_reads"]
    return {
        "rows": rows,
        "slope_per_us": slope,
        "r2": r2,
        "nonflag_reads": nonflag,
        "pass_linear": r2 > 0.99 and slope > 0,
        "pass_nonflag_66k": 60_000 <= nonflag <= 70_000,
        "paper_claim": "flag reads increase linearly with wakeupTime; "
                       "non-flag ~66K stable",
    }


# ---------------------------------------------------------------------------
# Figure 9: SyncMon bounds flag reads (paper: 728-788 across the sweep)
# ---------------------------------------------------------------------------


def fig9_syncmon(engine=EngineKind.EVENT) -> Dict:
    rows = []
    for i, d_us in enumerate(SWEEP_US):
        cfg = SimConfig(sync=SyncPolicy.SYNCMON, engine=engine)
        # calibrated 10 ns per-eGPU network jitter (EXPERIMENTS.md §SyncMon)
        p = GaussianPerturb(seed=i * 7 + 1, write_sigma_ns=10.0)
        r = run_gemv_allreduce(
            cfg, d_us * 1000.0, perturb=p, collect_segments=False
        )
        rows.append(
            {"wakeup_us": d_us, "flag_reads": r.flag_reads,
             "nonflag_reads": r.nonflag_reads,
             "monitor_wakes": r.monitor_stats.get("wakes", 0)}
        )
    reads = [r["flag_reads"] for r in rows]
    nonflag = rows[0]["nonflag_reads"]
    return {
        "rows": rows,
        "min_reads": min(reads),
        "max_reads": max(reads),
        "nonflag_reads": nonflag,
        "pass_bounded": (max(reads) - min(reads)) < 200
        and 700 <= min(reads)
        and max(reads) <= 800,
        "pass_nonflag_unchanged": 60_000 <= nonflag <= 70_000,
        "paper_claim": "flag reads bounded 728-788 across all configurations; "
                       "non-flag unchanged ~66K",
    }


# ---------------------------------------------------------------------------
# Figure 10: simulation wall time scales linearly with input dimension M
# ---------------------------------------------------------------------------


def fig10_scaling_m(engine=EngineKind.EVENT, repeats: int = 3) -> Dict:
    rows = []
    for M in (256, 512, 1024, 2048, 4096):
        cfg = SimConfig(M=M, sync=SyncPolicy.SPIN, engine=engine)
        times = []
        for _rep in range(repeats):
            t0 = time.perf_counter()
            run_gemv_allreduce(cfg, 10_000.0, collect_segments=False)
            times.append(time.perf_counter() - t0)
        rows.append({"M": M, "wall_s": float(np.median(times))})
    slope, icpt, r2 = _linfit_r2(
        [r["M"] for r in rows], [r["wall_s"] for r in rows]
    )
    return {
        "rows": rows,
        "r2": r2,
        "pass_linear": r2 >= 0.76,  # the paper's own weakest trendline fit
        "paper_claim": "sim time ~ linear in M (r^2 0.76-0.98)",
    }


# ---------------------------------------------------------------------------
# Figure 11: simulation time sub-linear in #eGPUs; fit t = t_1GPU + n*t_eGPU
# ---------------------------------------------------------------------------


def fig11_scaling_egpus(engine=EngineKind.EVENT, syncmon: bool = False) -> Dict:
    counts = [3, 7, 15, 31, 63, 127, 255]
    rows = []
    for n in counts:
        cfg = SimConfig(
            n_egpus=n,
            weak_scaling=True,  # per-device K slice held at K (paper's setup
            # keeps per-GPU work fixed while eidolons are added)
            K=2048,
            sync=SyncPolicy.SYNCMON if syncmon else SyncPolicy.SPIN,
            engine=engine,
        )
        t0 = time.perf_counter()
        r = run_gemv_allreduce(cfg, 10_000.0, collect_segments=False)
        wall = time.perf_counter() - t0
        rows.append({"egpus": n, "wall_s": wall, "wtt_writes": r.wtt_registered})
    # fit t = t_1 + n * t_e  (paper Eq. 1)
    ns = np.array([r["egpus"] for r in rows], float)
    ts = np.array([r["wall_s"] for r in rows], float)
    A = np.stack([np.ones_like(ns), ns], axis=1)
    (t1, te), *_ = np.linalg.lstsq(A, ts, rcond=None)
    t1 = max(t1, 1e-9)
    norm = ts / t1
    return {
        "rows": rows,
        "t_1gpu_s": float(t1),
        "t_egpu_s": float(te),
        "normalized_at_max": float(norm[-1]),
        "pass_sublinear": norm[-1] < (counts[-1] + 1) * 0.5,
        "paper_claim": "normalized time at 255 eGPUs in 7.3x-35.9x, far "
                       "below the 256x of full-detail simulation",
    }


# ---------------------------------------------------------------------------
# Figures 1/2: ideal vs. non-ideal timelines (variability characterization)
# ---------------------------------------------------------------------------


def fig12_variability() -> Dict:
    cfg = SimConfig(sync=SyncPolicy.SPIN, engine=EngineKind.EVENT)
    ideal = run_gemv_allreduce(cfg, 0.0)
    slow = run_gemv_allreduce(
        cfg, 0.0, perturb=PeerDelayPerturb({2: 30_000.0, 3: 30_000.0})
    )
    wait_i = phase_totals(ideal.segments).get("wait_flags", 0.0)
    wait_s = phase_totals(slow.segments).get("wait_flags", 0.0)
    return {
        "ideal_wait_ns_total": wait_i,
        "contended_wait_ns_total": wait_s,
        "wait_inflation": wait_s / max(wait_i, 1.0),
        "ideal_kernel_ns": ideal.kernel_span_ns,
        "contended_kernel_ns": slow.kernel_span_ns,
        "pass_inflation": wait_s > 10 * max(wait_i, 1.0),
        "ascii_ideal": ascii_timeline(ideal.segments, max_rows=6),
        "ascii_contended": ascii_timeline(slow.segments, max_rows=6),
        "paper_claim": "identical kernels show ideal vs. wait-dominated "
                       "timelines under transient peer delays (Figs. 1-2)",
    }


# ---------------------------------------------------------------------------
# engine comparison (paper §3.2.2: WTT polling vs event queues) + vector
# ---------------------------------------------------------------------------


def engine_comparison() -> Dict:
    rows = []
    for eng in (EngineKind.CYCLE, EngineKind.EVENT, EngineKind.VECTOR):
        cfg = SimConfig(sync=SyncPolicy.SPIN, engine=eng)
        t0 = time.perf_counter()
        r = run_gemv_allreduce(cfg, 20_000.0, collect_segments=False)
        rows.append(
            {
                "engine": eng.value,
                "wall_s": time.perf_counter() - t0,
                "flag_reads": r.flag_reads,
                "head_polls": r.wtt_head_polls,
            }
        )
    same = len({r["flag_reads"] for r in rows}) == 1
    return {
        "rows": rows,
        "pass_identical_traffic": same,
        "speedup_event_vs_cycle": rows[0]["wall_s"] / max(rows[1]["wall_s"], 1e-9),
        "speedup_vector_vs_cycle": rows[0]["wall_s"] / max(rows[2]["wall_s"], 1e-9),
    }
