"""Engine wall-time across registered scenarios — the perf trajectory baseline.

For every registered scenario and every engine that supports it, runs one
simulation at the default Table-1-scale configuration (both sync policies) and
records simulated span, traffic, and wall time.  Future performance PRs
compare against these rows.

Run: PYTHONPATH=src python -m benchmarks.scenario_sweep [--quick]
     [--out results/scenario_sweep.json]
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workgroup count (CI-friendly)")
    ap.add_argument("--out", default="results/scenario_sweep.json")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.core import EngineKind, SimConfig, SyncPolicy, list_scenarios, simulate

    base = SimConfig(workgroups=48) if args.quick else SimConfig()
    engines = (EngineKind.CYCLE, EngineKind.EVENT, EngineKind.VECTOR)

    rows = []
    print(f"{'scenario':16s} {'engine':7s} {'sync':8s} "
          f"{'flag_reads':>11s} {'span_ns':>12s} {'wall_ms':>9s}")
    for name in list_scenarios():
        for sync in (SyncPolicy.SPIN, SyncPolicy.SYNCMON):
            for eng in engines:
                cfg = base.with_(engine=eng, sync=sync)
                try:
                    r = simulate(name, cfg, collect_segments=False)
                except NotImplementedError:
                    continue  # vector engine is gemv-only
                rows.append({
                    "scenario": name,
                    "engine": eng.value,
                    "sync": sync.value,
                    "flag_reads": r.flag_reads,
                    "nonflag_reads": r.nonflag_reads,
                    "kernel_span_ns": r.kernel_span_ns,
                    "wall_time_s": r.wall_time_s,
                    "workgroups": cfg.workgroups,
                })
                print(f"{name:16s} {eng.value:7s} {sync.value:8s} "
                      f"{r.flag_reads:>11,} {r.kernel_span_ns:>12,.0f} "
                      f"{r.wall_time_s * 1e3:>9.2f}")

    # engines must agree on traffic per (scenario, sync) — a free
    # cross-engine regression check on every benchmark run
    agree = True
    by_case = {}
    for row in rows:
        by_case.setdefault((row["scenario"], row["sync"]), []).append(row)
    for case, group in sorted(by_case.items()):
        counts = {(g["flag_reads"], g["nonflag_reads"]) for g in group}
        if len(counts) != 1:
            agree = False
            print(f"[bench] ENGINE MISMATCH {case}: {counts}")
    print(f"[bench] scenario_sweep {'PASS' if agree else 'FAIL'} "
          f"({len(rows)} rows, {len(by_case)} cases)")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"rows": rows, "engines_agree": agree}, f, indent=1)
    print(f"[bench] wrote {args.out}")
    if not agree:
        sys.exit(1)


if __name__ == "__main__":
    main()
