"""Pallas-kernel microbenchmarks (interpret mode: correctness + shape sweep
timings; real TPU numbers come from running the same entry points with
``interpret=False``)."""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warmup/compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def gemv_sweep() -> Dict:
    rows: List[Dict] = []
    rng = jax.random.PRNGKey(0)
    for (M, K) in ((256, 2048), (512, 8192), (1024, 8192)):
        a = jax.random.normal(rng, (M, K), jnp.float32)
        x = jax.random.normal(rng, (K, 1), jnp.float32)
        t, y = _time(ops.gemv, a, x, bm=128, bk=512)
        err = float(jnp.max(jnp.abs(y - ref.gemv_ref(a, x))))
        rows.append({"M": M, "K": K, "us": t * 1e6, "max_err": err})
    return {"rows": rows, "pass": all(r["max_err"] < 1e-3 for r in rows)}


def decode_attention_sweep() -> Dict:
    rows: List[Dict] = []
    rng = jax.random.PRNGKey(1)
    for (B, H, KV, D, S) in ((1, 8, 2, 64, 1024), (4, 8, 8, 64, 2048)):
        q = jax.random.normal(rng, (B, H, D), jnp.float32)
        k = jax.random.normal(rng, (B, S, KV, D), jnp.float32)
        v = jax.random.normal(rng, (B, S, KV, D), jnp.float32)
        t, o = _time(ops.decode_attention, q, k, v, jnp.int32(S - 3), bs=256)
        err = float(jnp.max(jnp.abs(o - ref.decode_attention_ref(q, k, v, S - 3))))
        rows.append({"B": B, "H": H, "S": S, "us": t * 1e6, "max_err": err})
    return {"rows": rows, "pass": all(r["max_err"] < 1e-3 for r in rows)}


def all_benches() -> Dict[str, Dict]:
    return {"gemv": gemv_sweep(), "decode_attention": decode_attention_sweep()}
