"""Closed-loop multi-device scaling benchmark — the perf trajectory seed.

Sweeps device counts on the event engine for every closed-loop-capable
scenario and records simulated span, aggregate traffic, and wall time, so
future performance PRs have a multi-device baseline to compare against
(`BENCH_multi_device.json`).  A cross-engine spot check at the smallest
device count guards the cycle/event bit-identity on every benchmark run.

Run: PYTHONPATH=src python benchmarks/multi_device_bench.py
     [--quick] [--out BENCH_multi_device.json]
"""

from __future__ import annotations

import argparse
import json
import os
import sys


CLOSED_LOOP_SCENARIOS = ("ring_allreduce", "all_to_all", "pipeline_p2p")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny config + small device counts (CI smoke)")
    ap.add_argument("--out", default="BENCH_multi_device.json")
    ap.add_argument("--devices", default=None,
                    help="comma-separated device counts (default 4,8,16,32)")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.core import EngineKind, SimConfig, simulate

    if args.devices:
        device_counts = [int(x) for x in args.devices.split(",")]
    else:
        device_counts = [2, 4] if args.quick else [4, 8, 16, 32]
    base = SimConfig(
        workgroups=16 if args.quick else 64,
        engine=EngineKind.EVENT,
    )

    rows = []
    print(f"{'scenario':16s} {'devices':>7s} {'span_ns':>12s} "
          f"{'flag_reads':>11s} {'wtt_enacted':>11s} {'wall_ms':>9s}")
    for name in CLOSED_LOOP_SCENARIOS:
        for nd in device_counts:
            r = simulate(name, base, devices=nd, closed_loop=True,
                         collect_segments=False)
            rows.append({
                "scenario": name,
                "devices": nd,
                "engine": r.engine,
                "sync": r.sync,
                "workgroups": base.workgroups,
                "flag_reads": r.flag_reads,
                "nonflag_reads": r.nonflag_reads,
                "xgmi_writes_in": r.traffic.get("xgmi_writes_in", 0),
                "wtt_enacted": r.wtt_enacted,
                "kernel_span_ns": r.kernel_span_ns,
                "sim_cycles": r.sim_cycles,
                "wall_time_s": r.wall_time_s,
            })
            print(f"{name:16s} {nd:>7d} {r.kernel_span_ns:>12,.0f} "
                  f"{r.flag_reads:>11,} {r.wtt_enacted:>11,} "
                  f"{r.wall_time_s * 1e3:>9.2f}")

    # cross-engine spot check at the smallest device count: the cycle and
    # event engines must stay bit-identical in the closed loop
    agree = True
    nd = device_counts[0]
    for name in CLOSED_LOOP_SCENARIOS:
        pair = {}
        for eng in (EngineKind.CYCLE, EngineKind.EVENT):
            r = simulate(name, base.with_(engine=eng), devices=nd,
                         closed_loop=True, collect_segments=False)
            pair[eng.value] = (r.flag_reads, r.nonflag_reads, r.kernel_span_ns)
        if pair["cycle"] != pair["event"]:
            agree = False
            print(f"[bench] ENGINE MISMATCH {name} devices={nd}: {pair}")
    print(f"[bench] multi_device {'PASS' if agree else 'FAIL'} "
          f"({len(rows)} rows)")

    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"rows": rows, "engines_agree": agree}, f, indent=1)
    print(f"[bench] wrote {args.out}")
    if not agree:
        sys.exit(1)


if __name__ == "__main__":
    main()
