"""Closed-loop multi-device scaling benchmark — the perf trajectory seed.

Sweeps scenarios x fabric shapes on the event engine for every
closed-loop-capable scenario: each device count runs in the flat single-tier
shape, a tiered intra/inter-node shape (``devices_per_node`` = 2 below 16
devices, 4 from 16 up, 16 at pod scale), AND — same node split — on the
``fat_tree`` and
``rail_optimized`` interconnect presets, recording simulated span, aggregate
traffic, and wall time, so future performance PRs have a multi-device
baseline to compare against (`BENCH_multi_device.json`).  A cross-engine
spot check at the smallest device count (all shapes) guards the cycle/event
bit-identity on every benchmark run.

``--check BASELINE.json`` turns the run into a regression guard: for every
row that also exists in the baseline (same scenario/devices/devices_per_node/
fabric/engine/sync/workgroups; rows predating the tiered fabric count as
flat, rows predating the pluggable fabric as preset-less) the traffic
counters must match bit-for-bit and wall time must not regress beyond
``--wall-factor`` (default 2x) — counters drifting means the simulation
physics changed, wall regressing means someone broke the cohort interpreter,
the event calendar, or the fabric router.  The guard also requires at least
one matched ``fat_tree`` and one matched ``rail_optimized`` row, so the
graph-based presets can never silently fall out of coverage.

Pod scale (1024+ devices) rides the timeline engine
(``repro.core.cohort_timeline``, auto-selected; rows still record
``engine="event"`` — same semantics — with ``engine_impl`` naming the
implementation).  Symbolic programs go further: the flat ring/all_to_all
pod rows engage the flat lockstep solver (``repro.core.lockstep``), and
the tiered ring/all_to_all/hierarchical pod rows — on two_tier,
fat_tree, and rail_optimized alike — engage the tiered solver
(``repro.core.lockstep_tiered``).  Hierarchical engagement is new: its
legacy flag pool used to overrun into the partial-tile region at pod
scale (first bad count: 724 devices at 4 per node, found by the
parametric layout prover in ``repro.analysis.layout``), which made
data-marker writes alias the broadcast flags and stale-satisfy the
``hbc_wait`` barriers.  The scenario now re-bases its partial region
with ``AddressMap.with_partial_clearance()``, so the tiered pod rows
solve in lockstep — and the bench *asserts* ``lockstep_reason ==
"engaged"`` on every tiered non-pipeline pod row, including the
32-devices-per-node hierarchical shapes at 1024 and 4096.

**Baseline note (intentional regeneration):** the clearance re-base
changes hierarchical_allreduce's pod-scale physics *by design* — the
legacy baseline's 1024/4096 hierarchical counters were measured against
stale-flag waits that completed early off aliased marker writes, so
``flag_reads``/``sim_cycles``/``kernel_span_ns`` on exactly those rows
differ from pre-PR-10 baselines.  Every other row (all scenarios below
724 devices, and all pipeline/ring/all_to_all rows at every count) is
bit-identical, verified with ``--check`` against the previous baseline
before regeneration.

``pipeline_p2p`` pod rows stay on the timeline engine (cross-group
pipelined chains), and the one exclusion left is the flat single-tier
hierarchical shape (genuinely program-size-bound: O(devices^2) phase
sites), printed with its reason, never silent.  Rows carry a
``wall_breakdown`` section-timing dict when the timeline engine or
lockstep solver ran; like ``wall_time_s`` and ``lockstep_reason`` it is
measurement metadata, not simulation physics, so ``--check`` ignores it.

Run: PYTHONPATH=src python benchmarks/multi_device_bench.py
     [--quick] [--devices 4,8,...] [--scenarios a,b] [--repeats N]
     [--check BENCH_multi_device.json] [--wall-factor 2.0]
     [--max-row-wall SECONDS] [--out BENCH_multi_device.json]
"""

from __future__ import annotations

import argparse
import gc
import json
import os
import sys


CLOSED_LOOP_SCENARIOS = (
    "ring_allreduce",
    "all_to_all",
    "pipeline_p2p",
    "hierarchical_allreduce",
)

# graph-based interconnect presets swept (with the tiered node split) in
# addition to the legacy flat/two_tier shapes
FABRIC_PRESETS = ("fat_tree", "rail_optimized")

# the simulation-physics outputs that must never drift between runs
COUNTER_KEYS = (
    "flag_reads",
    "nonflag_reads",
    "xgmi_writes_in",
    "wtt_enacted",
    "sim_cycles",
    "kernel_span_ns",
)


def tiered_dpn(devices: int) -> int:
    """The benchmark's tiered shape for one device count: 2-device nodes
    below 16 devices (so 4- and 8-device CI rows still split), 4-device
    nodes from 16 up, 16-device nodes from 4096 up.  The pod-scale bump is
    physical, not cosmetic: real 4096-accelerator machines ship larger
    scale-up domains, and the hierarchical leader ring is O(devices/dpn)
    steps per leader — 4-device nodes at 4096 devices would mean a
    1024-leader global ring, minutes of wall on any engine."""
    if devices < 16:
        return 2
    return 4 if devices < 4096 else 16


def pod_skip_reason(name: str, devices: int, dpn) -> str | None:
    """Why a (scenario, devices, shape) combination is excluded from the
    sweep, or None to run it.  Pod-scale coverage is deliberate, not silent:
    every exclusion prints its reason, and only genuinely
    program-size-bound shapes are excluded.

    * flat ring_allreduce / all_to_all at >= 1024 devices ride the flat
      lockstep solver (symbolic programs; closed-form rank x step advance);
    * tiered ring_allreduce / all_to_all / hierarchical_allreduce at
      >= 1024 ride the tiered lockstep solver
      (``repro.core.lockstep_tiered``): group-uniform bulk solving with
      multi-leg route pricing gives real seconds-scale rows on the
      two_tier, fat_tree, and rail_optimized presets — shapes that used
      to be skipped as timeline-minutes;
    * tiered hierarchical_allreduce additionally runs a 32-devices-per-
      node shape at >= 1024 (the physical scale-up-domain size), also in
      lockstep;
    * pipeline_p2p pod rows stay on the timeline engine (cross-group
      pipelined chains are outside any bulk solver's schedule), but its
      programs are O(microbatches), not O(devices), so the walk is
      seconds-scale and every shape runs;
    * flat single-tier hierarchical_allreduce at >= 1024 is the one
      genuinely program-size-bound shape left: with the whole pod as one
      node it degenerates to an O(devices)-step intra-node ring per
      device — O(devices^2) phase sites, hours of wall on any engine —
      and the flat shape exists only to contrast tier routing, which its
      tiered pod rows already pin.
    """
    if devices < 1024:
        return None
    if name == "hierarchical_allreduce" and dpn is None:
        return (
            "flat single-tier hierarchical_allreduce degenerates to an "
            f"O(devices)-step intra-node ring per device at {devices} "
            "devices (O(devices^2) phase sites, hours of wall on any "
            "engine); the tiered pod rows cover the scenario"
        )
    return None


def _row_key(row: dict) -> tuple:
    return (
        row["scenario"],
        row["devices"],
        # rows written before the tiered fabric carry no shape field (they
        # were flat by construction); rows predating the pluggable fabric
        # carry no preset name (topology-derived ring/two_tier)
        row.get("devices_per_node"),
        row.get("fabric"),
        row["engine"],
        row["sync"],
        row["workgroups"],
    )


def check_against_baseline(
    rows, baseline_path: str, wall_factor: float, wall_grace_s: float = 0.05
) -> list:
    """Return a list of human-readable failures ([] = guard passes).

    Counters are compared exactly.  Wall time fails only beyond
    ``factor * baseline + grace``: the absolute grace keeps few-millisecond
    rows from tripping on scheduler noise while still catching real
    complexity regressions (which cost tens of ms even at 4 devices).
    """
    with open(baseline_path) as f:
        baseline = {_row_key(r): r for r in json.load(f)["rows"]}
    failures = []
    matched = 0
    matched_fabrics = set()
    for row in rows:
        base = baseline.get(_row_key(row))
        if base is None:
            continue
        matched += 1
        matched_fabrics.add(row.get("fabric"))
        where = (
            f"{row['scenario']} devices={row['devices']} "
            f"dpn={row.get('devices_per_node')} fabric={row.get('fabric')}"
        )
        for k in COUNTER_KEYS:
            if row[k] != base[k]:
                failures.append(f"{where}: {k} drifted {base[k]} -> {row[k]}")
        if row["wall_time_s"] > wall_factor * base["wall_time_s"] + wall_grace_s:
            failures.append(
                f"{where}: wall time regressed "
                f"{base['wall_time_s'] * 1e3:.1f} ms -> "
                f"{row['wall_time_s'] * 1e3:.1f} ms (> {wall_factor:g}x)"
            )
    if not matched:
        failures.append(
            f"no rows matched the baseline {baseline_path} — check devices/"
            "workgroups flags"
        )
    for preset in FABRIC_PRESETS:
        if any(r.get("fabric") == preset for r in rows) and (
            preset not in matched_fabrics
        ):
            failures.append(
                f"no {preset!r} row matched the baseline {baseline_path} — "
                "the fabric-preset guard lost coverage (regenerate the "
                "baseline?)"
            )
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny config + small device counts (CI smoke)")
    ap.add_argument("--out", default=None,
                    help="output JSON (default: BENCH_multi_device.json for "
                         "a baseline-regeneration run; guard runs with "
                         "--check write nothing unless --out is given, so "
                         "checking never clobbers the committed baseline "
                         "with a partial sweep)")
    ap.add_argument("--devices", default=None,
                    help="comma-separated device counts "
                         "(default 4,8,16,32,64,128,256,1024,4096)")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated scenario filter "
                         "(default: all closed-loop scenarios)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="wall time = min over N runs (counters must agree)")
    ap.add_argument("--max-row-wall", type=float, default=None,
                    metavar="SECONDS",
                    help="fail if any row's wall time exceeds this budget "
                         "(the CI pod-smoke gate)")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="regression guard: compare counters (exact) and "
                         "wall time against this baseline JSON")
    ap.add_argument("--wall-factor", type=float, default=2.0,
                    help="max tolerated wall-time ratio vs baseline")
    ap.add_argument("--wall-grace", type=float, default=0.05,
                    help="absolute wall-time slack in seconds (scheduler "
                         "noise floor for few-ms rows)")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.core import EngineKind, SimConfig, simulate

    if args.devices:
        device_counts = [int(x) for x in args.devices.split(",")]
    else:
        device_counts = (
            [2, 4] if args.quick
            else [4, 8, 16, 32, 64, 128, 256, 1024, 4096]
        )
    if args.scenarios:
        scenarios = tuple(args.scenarios.split(","))
        unknown = set(scenarios) - set(CLOSED_LOOP_SCENARIOS)
        if unknown:
            ap.error(f"unknown scenarios: {sorted(unknown)}")
    else:
        scenarios = CLOSED_LOOP_SCENARIOS
    base = SimConfig(
        workgroups=16 if args.quick else 64,
        engine=EngineKind.EVENT,
    )

    def shapes_for(name: str, nd: int):
        """(devices_per_node, fabric) shapes one (scenario, device count)
        runs in: flat, two-tier, and each graph-based preset on the tiered
        node split; hierarchical pod counts add a 32-device-node shape
        (the physical scale-up-domain size — rides the tiered solver)."""
        out = [(None, None), (tiered_dpn(nd), None)]
        out.extend((tiered_dpn(nd), f) for f in FABRIC_PRESETS)
        if name == "hierarchical_allreduce" and nd >= 1024:
            out.append((32, None))
        return [(dpn, fab) for dpn, fab in out
                if dpn is None or nd % dpn == 0]

    rows = []
    print(f"{'scenario':22s} {'devices':>7s} {'dpn':>4s} {'fabric':>15s} "
          f"{'span_ns':>12s} {'flag_reads':>11s} {'wtt_enacted':>11s} "
          f"{'wall_ms':>9s}")
    for name in scenarios:
        for nd in device_counts:
            for dpn, fab in shapes_for(name, nd):
                skip = pod_skip_reason(name, nd, dpn)
                if skip is not None:
                    print(f"[bench] skip {name} devices={nd} "
                          f"dpn={dpn or '-'} fabric={fab or '-'}: {skip}")
                    continue
                best = None
                for _ in range(max(1, args.repeats)):
                    # pod-scale rows leave multi-GB heaps behind; collect
                    # before timing so each row's wall measures its own
                    # work, not the previous row's garbage
                    gc.collect()
                    r = simulate(name, base, devices=nd, closed_loop=True,
                                 devices_per_node=dpn, fabric=fab,
                                 collect_segments=False)
                    row = {
                        "scenario": name,
                        "devices": nd,
                        "devices_per_node": dpn,
                        "fabric": fab,
                        "engine": r.engine,
                        "sync": r.sync,
                        "workgroups": base.workgroups,
                        "flag_reads": r.flag_reads,
                        "nonflag_reads": r.nonflag_reads,
                        "xgmi_writes_in": r.traffic.get("xgmi_writes_in", 0),
                        "wtt_enacted": r.wtt_enacted,
                        "kernel_span_ns": r.kernel_span_ns,
                        "sim_cycles": r.sim_cycles,
                        "wall_time_s": r.wall_time_s,
                        # implementation metadata, not simulation physics:
                        # --check ignores these (it compares COUNTER_KEYS)
                        "engine_impl": r.meta.get("engine_impl"),
                        "wall_breakdown": r.meta.get("wall_breakdown"),
                        "lockstep_reason": r.meta.get("lockstep_reason"),
                    }
                    if best is not None:
                        for k in COUNTER_KEYS:
                            assert row[k] == best[k], (
                                f"nondeterministic {k}: {row[k]} != {best[k]}"
                            )
                    if best is None or row["wall_time_s"] < best["wall_time_s"]:
                        best = row
                rows.append(best)
                print(f"{name:22s} {nd:>7d} {dpn or '-':>4} "
                      f"{fab or '-':>15s} "
                      f"{best['kernel_span_ns']:>12,.0f} "
                      f"{best['flag_reads']:>11,} {best['wtt_enacted']:>11,} "
                      f"{best['wall_time_s'] * 1e3:>9.2f}")

    # cross-engine spot check at the smallest device count, in both the flat
    # and the tiered shape: the cycle and event engines must stay
    # bit-identical in the closed loop.  The cycle engine steps every cycle,
    # so the check is only practical at small counts — a large-count-only
    # invocation (baseline regeneration in chunks) skips it and relies on the
    # small-count runs for the identity guard.
    agree = True
    nd = min(device_counts)
    if nd > 32:
        print(f"[bench] cross-engine spot check skipped (smallest count "
              f"{nd} > 32; cycle engine impractical)")
        spot_scenarios = ()
    else:
        spot_scenarios = scenarios
    for name in spot_scenarios:
        for dpn, fab in shapes_for(name, nd):
            pair = {}
            for eng in (EngineKind.CYCLE, EngineKind.EVENT):
                r = simulate(name, base.with_(engine=eng), devices=nd,
                             closed_loop=True, devices_per_node=dpn,
                             fabric=fab, collect_segments=False)
                pair[eng.value] = (
                    r.flag_reads, r.nonflag_reads, r.kernel_span_ns
                )
            if pair["cycle"] != pair["event"]:
                agree = False
                print(f"[bench] ENGINE MISMATCH {name} devices={nd} "
                      f"dpn={dpn} fabric={fab}: {pair}")
    print(f"[bench] multi_device {'PASS' if agree else 'FAIL'} "
          f"({len(rows)} rows)")

    failures = []
    # tiered non-pipeline pod rows must ride the lockstep solver — an
    # accidental fallback to the timeline walk (e.g. a layout regression
    # reintroducing marker aliasing) is a coverage loss, not just a slow row
    for row in rows:
        if (row["devices"] >= 1024
                and row.get("devices_per_node") is not None
                and row["scenario"] != "pipeline_p2p"
                and row.get("lockstep_reason") != "engaged"):
            failures.append(
                f"{row['scenario']} devices={row['devices']} "
                f"dpn={row.get('devices_per_node')} "
                f"fabric={row.get('fabric')}: tiered pod row did not engage "
                f"the lockstep solver "
                f"(lockstep_reason={row.get('lockstep_reason')!r})"
            )
    for f_ in failures:
        print(f"[bench] LOCKSTEP {f_}")
    if args.max_row_wall is not None:
        for row in rows:
            if row["wall_time_s"] > args.max_row_wall:
                failures.append(
                    f"{row['scenario']} devices={row['devices']} "
                    f"dpn={row.get('devices_per_node')} "
                    f"fabric={row.get('fabric')}: wall "
                    f"{row['wall_time_s']:.1f} s exceeds the "
                    f"--max-row-wall budget ({args.max_row_wall:g} s)"
                )
        for f_ in failures:
            print(f"[bench] BUDGET {f_}")
        print(f"[bench] row wall budget "
              f"{'PASS' if not failures else 'FAIL'} "
              f"({args.max_row_wall:g} s)")
    if args.check:
        check_failures = check_against_baseline(
            rows, args.check, args.wall_factor, args.wall_grace
        )
        for f_ in check_failures:
            print(f"[bench] REGRESSION {f_}")
        print(f"[bench] baseline check "
              f"{'PASS' if not check_failures else 'FAIL'} vs {args.check}")
        failures += check_failures

    out = args.out
    if out is None:
        out = None if args.check else "BENCH_multi_device.json"
    if out is not None:
        out_dir = os.path.dirname(out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(out, "w") as f:
            json.dump({"rows": rows, "engines_agree": agree}, f, indent=1)
        print(f"[bench] wrote {out}")
    else:
        print("[bench] no --out given on a --check run; nothing written")
    if not agree or failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
